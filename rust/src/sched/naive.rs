//! Naive ping-pong codegen (Fig. 3b).
//!
//! Active macros are split into two banks.  While bank A computes chunk
//! `p`, bank B rewrites chunk `p+1`; a global barrier swaps the roles.
//! The phase length is `max(time_PIM, bank-write-time)` — whenever the two
//! differ, the faster side idles: the pipeline bubble the paper's Fig. 4
//! quantifies and generalized ping-pong removes.

use super::plan::{tile_id, SchedulePlan};
use crate::arch::ArchConfig;
use crate::isa::{Inst, Program};

/// One task placement: which core/macro executes which task.
type Assign = (u32, u8, u32); // (core, local macro, task)

/// The global slot space, core-major: the bank boundary is chip-wide
/// (the bus is global, so the bank split must be too), and the slot
/// index doubles as the representative tile of the looped lowering.
fn bank_slots(arch: &ArchConfig, plan: &SchedulePlan) -> Vec<(u32, u8)> {
    let mut slots: Vec<(u32, u8)> = Vec::new();
    for core in 0..arch.n_cores {
        for &m in &plan.macros_on_core(arch, core) {
            slots.push((core, m));
        }
    }
    slots
}

/// Split each core's active macros into bank A (first half, rounded up)
/// and bank B; assemble the global phase table: phase p's assignments are
/// computed by bank `p % 2` and were written during phase `p-1` (phase 0's
/// writes form the prologue).
fn phase_table(arch: &ArchConfig, plan: &SchedulePlan) -> Vec<Vec<Assign>> {
    let slots = bank_slots(arch, plan);
    let half = slots.len().div_ceil(2);
    let bank_a = &slots[..half];
    let bank_b = &slots[half..];

    let mut phases: Vec<Vec<Assign>> = Vec::new();
    let mut task = 0u32;
    while task < plan.tasks {
        // Degenerate single-bank chip (1 active macro): every phase runs
        // on bank A and the codegen serializes write-after-compute.
        let use_a = phases.len() % 2 == 0 || bank_b.is_empty();
        let bank = if use_a { bank_a } else { bank_b };
        let mut assign = Vec::new();
        for &(core, m) in bank {
            if task >= plan.tasks {
                break;
            }
            assign.push((core, m, task));
            task += 1;
        }
        phases.push(assign);
    }
    phases
}

/// Emit one bank-swap phase: the compute batch, the other bank's
/// prefetch writes (concurrently — except writes targeting a macro still
/// computing this phase, the degenerate single-bank case: those go after
/// waitc), the waits on both banks, and the swap barrier.  `computing`
/// and `writing` carry `(macro, tile)` pairs — real task tiles in the
/// unrolled form, representative slot tiles in the rolled loop body.
fn emit_phase(insts: &mut Vec<Inst>, n_vec: u16, computing: &[(u8, u32)], writing: &[(u8, u32)]) {
    let computing_macros: Vec<u8> = computing.iter().map(|&(m, _)| m).collect();
    for &(m, tile) in computing {
        insts.push(Inst::LdIn { n_vec });
        insts.push(Inst::Vmm { m, n_vec, tile });
    }
    for &(m, tile) in writing {
        if !computing_macros.contains(&m) {
            insts.push(Inst::Wrw { m, tile });
        }
    }
    // The swap happens when BOTH banks are done.
    for &(m, _) in computing {
        insts.push(Inst::WaitC { m });
        insts.push(Inst::StOut { n_vec });
    }
    for &(m, tile) in writing {
        if computing_macros.contains(&m) {
            insts.push(Inst::Wrw { m, tile });
        }
    }
    for &(m, _) in writing {
        insts.push(Inst::WaitW { m });
    }
    insts.push(Inst::Barrier);
}

/// Generate the naive ping-pong program: one stream per core, barriers at
/// every bank swap.
pub fn codegen(arch: &ArchConfig, plan: &SchedulePlan) -> Program {
    let phases = phase_table(arch, plan);
    let mut program = Program::new(arch.n_cores);
    let n_vec = plan.n_in as u16;

    for core in 0..arch.n_cores {
        if plan.macros_on_core(arch, core).is_empty() {
            continue;
        }
        let mine = |phase: &[Assign]| -> Vec<(u8, u32)> {
            phase
                .iter()
                .filter(|(c, _, _)| *c == core)
                .map(|&(_, m, t)| (m, tile_id(t)))
                .collect()
        };

        let mut insts = vec![Inst::SetSpd {
            speed: plan.write_speed as u16,
        }];

        // Prologue: load phase 0's tiles into bank A.
        if let Some(first) = phases.first() {
            for (m, tile) in mine(first) {
                insts.push(Inst::Wrw { m, tile });
            }
            for (m, _) in mine(first) {
                insts.push(Inst::WaitW { m });
            }
        }
        insts.push(Inst::Barrier);

        for p in 0..phases.len() {
            let computing = mine(&phases[p]);
            let writing: Vec<(u8, u32)> = phases.get(p + 1).map(|ph| mine(ph)).unwrap_or_default();
            emit_phase(&mut insts, n_vec, &computing, &writing);
        }
        insts.push(Inst::Halt);
        program.add_stream(core, insts);
    }

    // Barrier symmetry: every emitted stream has 1 + phases.len()
    // barriers by construction.
    program
}

/// The looped form of [`codegen`]: the steady 2-phase bank period (bank A
/// computes while bank B prefetches, then the roles swap) is rolled into
/// one `Inst::Loop` per core stream with representative slot tiles, the
/// ragged tail phases stay unrolled.  A pair of phases is rollable only
/// while every phase it touches — including the *write target* of its
/// second half — is full (all bank slots assigned), so the loop body is
/// structurally identical across iterations.  Timing-identical to the
/// unrolled form at `issue_cost == 0` (tile ids never influence timing);
/// see [`crate::sched::CodegenStyle::Looped`].
pub fn codegen_looped(arch: &ArchConfig, plan: &SchedulePlan) -> Program {
    let phases = phase_table(arch, plan);
    let slots = bank_slots(arch, plan);
    let half = slots.len().div_ceil(2);
    let bank_b_empty = slots.len() <= half;
    // Phase p is full when every slot of its bank got a task.
    let full = |p: usize| -> bool {
        phases.get(p).is_some_and(|ph| {
            let expected = if bank_b_empty || p % 2 == 0 {
                half
            } else {
                slots.len() - half
            };
            ph.len() == expected
        })
    };
    // Pair k covers phases 2k (computes A, prefetches B) and 2k+1
    // (computes B, prefetches A = phases[2k+2]); all three must be full.
    let mut pairs = 0usize;
    while full(2 * pairs) && full(2 * pairs + 1) && full(2 * pairs + 2) {
        pairs += 1;
    }
    let use_loop = pairs >= 2;
    let mut program = Program::new(arch.n_cores);
    let n_vec = plan.n_in as u16;

    for core in 0..arch.n_cores {
        if plan.macros_on_core(arch, core).is_empty() {
            continue;
        }
        let mine = |phase: &[Assign]| -> Vec<(u8, u32)> {
            phase
                .iter()
                .filter(|(c, _, _)| *c == core)
                .map(|&(_, m, t)| (m, tile_id(t)))
                .collect()
        };
        // Representative tile of a macro: its global slot index — fixed
        // across iterations, so written and computed tiles stay
        // consistent through the rolled loop.
        let rep = |phase: &[Assign]| -> Vec<(u8, u32)> {
            phase
                .iter()
                .filter(|(c, _, _)| *c == core)
                .map(|&(cc, m, _)| {
                    let slot = slots
                        .iter()
                        .position(|&(c2, m2)| c2 == cc && m2 == m)
                        .expect("assigned macro is an active slot");
                    (m, tile_id(slot as u32))
                })
                .collect()
        };

        let mut insts = vec![Inst::SetSpd {
            speed: plan.write_speed as u16,
        }];

        // Prologue: load phase 0's tiles into bank A — representative
        // tiles when phase 0 is computed inside the loop.
        if let Some(first) = phases.first() {
            let tiles = if use_loop { rep(first) } else { mine(first) };
            for &(m, tile) in &tiles {
                insts.push(Inst::Wrw { m, tile });
            }
            for &(m, _) in &tiles {
                insts.push(Inst::WaitW { m });
            }
        }
        insts.push(Inst::Barrier);

        let tail_start = if use_loop {
            insts.push(Inst::Loop {
                count: pairs as u32,
            });
            emit_phase(&mut insts, n_vec, &rep(&phases[0]), &rep(&phases[1]));
            emit_phase(&mut insts, n_vec, &rep(&phases[1]), &rep(&phases[2]));
            insts.push(Inst::EndLoop);
            2 * pairs
        } else {
            0
        };
        for p in tail_start..phases.len() {
            // The first tail phase computes the tiles the last loop
            // iteration prefetched — representative ones.
            let computing = if use_loop && p == tail_start {
                rep(&phases[p])
            } else {
                mine(&phases[p])
            };
            let writing: Vec<(u8, u32)> = phases.get(p + 1).map(|ph| mine(ph)).unwrap_or_default();
            emit_phase(&mut insts, n_vec, &computing, &writing);
        }
        insts.push(Inst::Halt);
        program.add_stream(core, insts);
    }
    program
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, SimOptions};

    fn arch() -> ArchConfig {
        ArchConfig::paper_default() // tp = tr = 128 at s=8, n_in=4
    }

    #[test]
    fn validates() {
        let a = arch();
        let plan = SchedulePlan::full_chip(&a, 512);
        codegen(&a, &plan).validate(a.macros_per_core).unwrap();
    }

    #[test]
    fn balanced_case_perfect_pipeline() {
        // tp == tr == 128, 2 macros (1 per bank), 8 tasks, ample band:
        // prologue 128 + 8 phases of 128 = 1152.
        let mut a = arch();
        a.bandwidth = 1024;
        let plan = SchedulePlan {
            tasks: 8,
            active_macros: 2,
            n_in: 4,
            write_speed: 8,
        };
        let p = codegen(&a, &plan);
        let r = simulate(&a, &p, SimOptions::default()).unwrap();
        assert_eq!(r.stats.cycles, 128 + 8 * 128);
        assert_eq!(r.stats.vmms_completed, 8);
    }

    #[test]
    fn compute_heavy_leaves_write_bubble() {
        // n_in = 32 => tp = 1024, tr = 128: phase = max = 1024.
        // 2 macros, 4 tasks: 128 prologue + 4*1024.
        let mut a = arch();
        a.bandwidth = 1024;
        a.core_buffer_bytes = 1 << 20;
        let plan = SchedulePlan {
            tasks: 4,
            active_macros: 2,
            n_in: 32,
            write_speed: 8,
        };
        let p = codegen(&a, &plan);
        let r = simulate(&a, &p, SimOptions::default()).unwrap();
        assert_eq!(r.stats.cycles, 128 + 4 * 1024);
        // Macro utilization ≈ naive_pingpong_util(1024,128) = 1152/2048.
        let util = r.stats.macro_utilization_active();
        let expect = crate::model::eqs::naive_pingpong_util(1024.0, 128.0);
        assert!((util - expect).abs() < 0.06, "util {util} vs {expect}");
    }

    #[test]
    fn write_heavy_leaves_compute_bubble() {
        // s = 1 => tr = 1024, tp = 128: phase = 1024.
        let mut a = arch();
        a.bandwidth = 1024;
        let plan = SchedulePlan {
            tasks: 4,
            active_macros: 2,
            n_in: 4,
            write_speed: 1,
        };
        let p = codegen(&a, &plan);
        let r = simulate(&a, &p, SimOptions::default()).unwrap();
        // Prologue write 1024, three write-bound phases of 1024, and a
        // final drain phase that only computes (128).
        assert_eq!(r.stats.cycles, 1024 + 3 * 1024 + 128);
    }

    #[test]
    fn single_macro_degenerates_to_insitu() {
        // 1 active macro: bank B empty — phases all on bank A, i.e.
        // serialized write→compute (no overlap possible).
        let a = arch();
        let plan = SchedulePlan {
            tasks: 3,
            active_macros: 1,
            n_in: 4,
            write_speed: 8,
        };
        let p = codegen(&a, &plan);
        let r = simulate(&a, &p, SimOptions::default()).unwrap();
        assert_eq!(r.stats.vmms_completed, 3);
        // Phase p computes task p but also prefetches task p+1 into the
        // same bank — wait, bank B is empty so tasks go A,A,A with the
        // *next* write starting only after the compute (write-during-
        // compute is illegal and the generator must respect it).
        assert!(r.stats.cycles >= 3 * 256);
    }

    #[test]
    fn full_chip_all_tasks_complete() {
        let a = arch();
        let plan = SchedulePlan::full_chip(&a, 300);
        let p = codegen(&a, &plan);
        let r = simulate(&a, &p, SimOptions::default()).unwrap();
        assert_eq!(r.stats.vmms_completed, 300);
        assert_eq!(r.stats.writes_completed, 300);
    }

    #[test]
    fn looped_codegen_is_stat_identical_to_unrolled() {
        let mut a = arch();
        a.core_buffer_bytes = 1 << 20;
        for (tasks, active, n_in, band, s) in [
            (64u32, 8u32, 4u32, 1024u64, 8u32), // balanced, even banks
            (50, 7, 12, 16, 8),                 // odd banks, ragged tail, narrow bus
            (37, 5, 4, 64, 1),                  // write-heavy
            (9, 4, 2, 8, 8),                    // too short to roll: stays unrolled
            (3, 1, 4, 512, 8),                  // degenerate single bank
            (8, 2, 4, 1024, 8),                 // exact multiple: empty final writes
        ] {
            a.bandwidth = band;
            let plan = SchedulePlan {
                tasks,
                active_macros: active,
                n_in,
                write_speed: s,
            };
            let unrolled = simulate(&a, &codegen(&a, &plan), SimOptions::default()).unwrap();
            let looped = simulate(&a, &codegen_looped(&a, &plan), SimOptions::default()).unwrap();
            assert_eq!(
                unrolled.stats, looped.stats,
                "tasks={tasks} active={active} n_in={n_in} band={band} s={s}"
            );
            codegen_looped(&a, &plan).validate(a.macros_per_core).unwrap();
        }
    }

    #[test]
    fn looped_codegen_rolls_the_two_phase_period() {
        let a = arch();
        let plan = SchedulePlan::full_chip(&a, 1024);
        let p = codegen_looped(&a, &plan);
        p.validate(a.macros_per_core).unwrap();
        let loops = p
            .streams
            .iter()
            .flat_map(|s| &s.insts)
            .filter(|i| matches!(i, Inst::Loop { .. }))
            .count();
        // One rolled 2-phase loop per core stream.
        assert_eq!(loops, a.n_cores as usize);
        // 1024 tasks on 256 macros = 8 phases of 128 tasks: 3 full
        // rollable pairs (the last pair's second phase prefetches
        // nothing, so it stays unrolled).
        for s in &p.streams {
            if let Some(Inst::Loop { count }) = s
                .insts
                .iter()
                .find(|i| matches!(i, Inst::Loop { .. }))
            {
                assert_eq!(*count, 3);
            }
        }
    }
}

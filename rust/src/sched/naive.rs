//! Naive ping-pong codegen (Fig. 3b).
//!
//! Active macros are split into two banks.  While bank A computes chunk
//! `p`, bank B rewrites chunk `p+1`; a global barrier swaps the roles.
//! The phase length is `max(time_PIM, bank-write-time)` — whenever the two
//! differ, the faster side idles: the pipeline bubble the paper's Fig. 4
//! quantifies and generalized ping-pong removes.

use super::plan::{tile_id, SchedulePlan};
use crate::arch::ArchConfig;
use crate::isa::{Inst, Program};

/// One task placement: which core/macro executes which task.
type Assign = (u32, u8, u32); // (core, local macro, task)

/// Split each core's active macros into bank A (first half, rounded up)
/// and bank B; assemble the global phase table: phase p's assignments are
/// computed by bank `p % 2` and were written during phase `p-1` (phase 0's
/// writes form the prologue).
fn phase_table(arch: &ArchConfig, plan: &SchedulePlan) -> Vec<Vec<Assign>> {
    // Banks split the *global* slot space in half (slots are core-major,
    // so bank A is the first half of active macros chip-wide) — the bus
    // is global, so the bank boundary must be too.
    let mut slots: Vec<(u32, u8)> = Vec::new();
    for core in 0..arch.n_cores {
        for &m in &plan.macros_on_core(arch, core) {
            slots.push((core, m));
        }
    }
    let half = slots.len().div_ceil(2);
    let bank_a = &slots[..half];
    let bank_b = &slots[half..];

    let mut phases: Vec<Vec<Assign>> = Vec::new();
    let mut task = 0u32;
    while task < plan.tasks {
        // Degenerate single-bank chip (1 active macro): every phase runs
        // on bank A and the codegen serializes write-after-compute.
        let use_a = phases.len() % 2 == 0 || bank_b.is_empty();
        let bank = if use_a { bank_a } else { bank_b };
        let mut assign = Vec::new();
        for &(core, m) in bank {
            if task >= plan.tasks {
                break;
            }
            assign.push((core, m, task));
            task += 1;
        }
        phases.push(assign);
    }
    phases
}

/// Generate the naive ping-pong program: one stream per core, barriers at
/// every bank swap.
pub fn codegen(arch: &ArchConfig, plan: &SchedulePlan) -> Program {
    let phases = phase_table(arch, plan);
    let mut program = Program::new(arch.n_cores);
    let n_vec = plan.n_in as u16;

    for core in 0..arch.n_cores {
        if plan.macros_on_core(arch, core).is_empty() {
            continue;
        }
        let mine = |phase: &[Assign]| -> Vec<(u8, u32)> {
            phase
                .iter()
                .filter(|(c, _, _)| *c == core)
                .map(|&(_, m, t)| (m, t))
                .collect()
        };

        let mut insts = vec![Inst::SetSpd {
            speed: plan.write_speed as u16,
        }];

        // Prologue: load phase 0's tiles into bank A.
        if let Some(first) = phases.first() {
            for (m, t) in mine(first) {
                insts.push(Inst::Wrw { m, tile: tile_id(t) });
            }
            for (m, _) in mine(first) {
                insts.push(Inst::WaitW { m });
            }
        }
        insts.push(Inst::Barrier);

        for p in 0..phases.len() {
            let computing = mine(&phases[p]);
            let writing: Vec<(u8, u32)> = phases.get(p + 1).map(|ph| mine(ph)).unwrap_or_default();
            let computing_macros: Vec<u8> = computing.iter().map(|&(m, _)| m).collect();
            // Issue the compute batch...
            for &(m, t) in &computing {
                insts.push(Inst::LdIn { n_vec });
                insts.push(Inst::Vmm {
                    m,
                    n_vec,
                    tile: tile_id(t),
                });
            }
            // ...and the other bank's prefetch writes, concurrently —
            // except writes that target a macro still computing this
            // phase (degenerate single-bank case): those go after waitc.
            for &(m, t) in &writing {
                if !computing_macros.contains(&m) {
                    insts.push(Inst::Wrw { m, tile: tile_id(t) });
                }
            }
            // The swap happens when BOTH banks are done.
            for &(m, _) in &computing {
                insts.push(Inst::WaitC { m });
                insts.push(Inst::StOut { n_vec });
            }
            for &(m, t) in &writing {
                if computing_macros.contains(&m) {
                    insts.push(Inst::Wrw { m, tile: tile_id(t) });
                }
            }
            for &(m, _) in &writing {
                insts.push(Inst::WaitW { m });
            }
            insts.push(Inst::Barrier);
        }
        insts.push(Inst::Halt);
        program.add_stream(core, insts);
    }

    // Barrier symmetry: every emitted stream has 1 + phases.len()
    // barriers by construction.
    program
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, SimOptions};

    fn arch() -> ArchConfig {
        ArchConfig::paper_default() // tp = tr = 128 at s=8, n_in=4
    }

    #[test]
    fn validates() {
        let a = arch();
        let plan = SchedulePlan::full_chip(&a, 512);
        codegen(&a, &plan).validate(a.macros_per_core).unwrap();
    }

    #[test]
    fn balanced_case_perfect_pipeline() {
        // tp == tr == 128, 2 macros (1 per bank), 8 tasks, ample band:
        // prologue 128 + 8 phases of 128 = 1152.
        let mut a = arch();
        a.bandwidth = 1024;
        let plan = SchedulePlan {
            tasks: 8,
            active_macros: 2,
            n_in: 4,
            write_speed: 8,
        };
        let p = codegen(&a, &plan);
        let r = simulate(&a, &p, SimOptions::default()).unwrap();
        assert_eq!(r.stats.cycles, 128 + 8 * 128);
        assert_eq!(r.stats.vmms_completed, 8);
    }

    #[test]
    fn compute_heavy_leaves_write_bubble() {
        // n_in = 32 => tp = 1024, tr = 128: phase = max = 1024.
        // 2 macros, 4 tasks: 128 prologue + 4*1024.
        let mut a = arch();
        a.bandwidth = 1024;
        a.core_buffer_bytes = 1 << 20;
        let plan = SchedulePlan {
            tasks: 4,
            active_macros: 2,
            n_in: 32,
            write_speed: 8,
        };
        let p = codegen(&a, &plan);
        let r = simulate(&a, &p, SimOptions::default()).unwrap();
        assert_eq!(r.stats.cycles, 128 + 4 * 1024);
        // Macro utilization ≈ naive_pingpong_util(1024,128) = 1152/2048.
        let util = r.stats.macro_utilization_active();
        let expect = crate::model::eqs::naive_pingpong_util(1024.0, 128.0);
        assert!((util - expect).abs() < 0.06, "util {util} vs {expect}");
    }

    #[test]
    fn write_heavy_leaves_compute_bubble() {
        // s = 1 => tr = 1024, tp = 128: phase = 1024.
        let mut a = arch();
        a.bandwidth = 1024;
        let plan = SchedulePlan {
            tasks: 4,
            active_macros: 2,
            n_in: 4,
            write_speed: 1,
        };
        let p = codegen(&a, &plan);
        let r = simulate(&a, &p, SimOptions::default()).unwrap();
        // Prologue write 1024, three write-bound phases of 1024, and a
        // final drain phase that only computes (128).
        assert_eq!(r.stats.cycles, 1024 + 3 * 1024 + 128);
    }

    #[test]
    fn single_macro_degenerates_to_insitu() {
        // 1 active macro: bank B empty — phases all on bank A, i.e.
        // serialized write→compute (no overlap possible).
        let a = arch();
        let plan = SchedulePlan {
            tasks: 3,
            active_macros: 1,
            n_in: 4,
            write_speed: 8,
        };
        let p = codegen(&a, &plan);
        let r = simulate(&a, &p, SimOptions::default()).unwrap();
        assert_eq!(r.stats.vmms_completed, 3);
        // Phase p computes task p but also prefetches task p+1 into the
        // same bank — wait, bank B is empty so tasks go A,A,A with the
        // *next* write starting only after the compute (write-during-
        // compute is illegal and the generator must respect it).
        assert!(r.stats.cycles >= 3 * 256);
    }

    #[test]
    fn full_chip_all_tasks_complete() {
        let a = arch();
        let plan = SchedulePlan::full_chip(&a, 300);
        let p = codegen(&a, &plan);
        let r = simulate(&a, &p, SimOptions::default()).unwrap();
        assert_eq!(r.stats.vmms_completed, 300);
        assert_eq!(r.stats.writes_completed, 300);
    }
}

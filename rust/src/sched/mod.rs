//! The three concurrent write/compute scheduling strategies as ISA code
//! generators (paper §II-B, §III).
//!
//! A strategy turns a [`SchedulePlan`] — how many macros, how many
//! tile-tasks, what batch size and write speed — into a [`Program`] for
//! the simulator:
//!
//! - [`insitu`]: one stream per core, global barriers around the
//!   synchronized write and compute phases (Fig. 3a).
//! - [`naive`]: one stream per core, macros split in two banks that
//!   alternate compute/write with a barrier at each swap (Fig. 3b).
//! - [`generalized`]: **one stream per macro**, start times staggered so
//!   the off-chip bus sees a constant writer population (Fig. 3c) — no
//!   barriers at all.
//!
//! Tile-task `t` is globally identified, and every strategy computes the
//! same task set, so simulated execution times are directly comparable.
//!
//! [`Program`]: crate::isa::Program

pub mod generalized;
pub mod insitu;
pub mod intra;
pub mod naive;
mod plan;

pub use plan::{tile_id, SchedulePlan, ScheduleError};

use crate::arch::ArchConfig;
use crate::isa::Program;

/// How a strategy's schedule is lowered to ISA code.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum CodegenStyle {
    /// Fully unrolled task list with globally-unique tile ids — the
    /// faithful form for op-log consumers (the coordinator's numerics
    /// replay identifies weight tiles by id).
    #[default]
    Unrolled,
    /// Steady-state iterations rolled into `Inst::Loop` with one
    /// representative tile per stream/macro.  Cycle- and stats-identical
    /// to [`CodegenStyle::Unrolled`] at `issue_cost == 0` (asserted by
    /// `tests/fast_forward.rs`), but op-log tile ids are no longer
    /// globally unique — use for timing-only evaluation (DSE, serving
    /// capacity models), where the rolled loops unlock the engine's
    /// steady-state fast-forward: simulated cost O(distinct phases)
    /// instead of O(tasks).
    Looped,
}

impl CodegenStyle {
    /// Short name for CLI/report output.
    pub fn name(&self) -> &'static str {
        match self {
            CodegenStyle::Unrolled => "unrolled",
            CodegenStyle::Looped => "looped",
        }
    }
}

/// Strategy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Synchronize all macros: write phase, then compute phase (Fig. 3a).
    InSitu,
    /// Two banks alternate computing and rewriting (Fig. 3b).
    NaivePingPong,
    /// Per-macro double buffering: write one partition while the other
    /// computes (the intra-macro realization of ping-pong, §II-B).
    IntraMacroPingPong,
    /// Staggered per-macro pipelining — the paper's contribution (Fig. 3c).
    GeneralizedPingPong,
}

impl Strategy {
    /// The paper's three-way comparison set (Fig. 3 / Fig. 6 / Fig. 7).
    pub const ALL: [Strategy; 3] = [
        Strategy::InSitu,
        Strategy::NaivePingPong,
        Strategy::GeneralizedPingPong,
    ];

    /// Every implemented strategy, including the intra-macro variant.
    pub const ALL_EXTENDED: [Strategy; 4] = [
        Strategy::InSitu,
        Strategy::NaivePingPong,
        Strategy::IntraMacroPingPong,
        Strategy::GeneralizedPingPong,
    ];

    /// Short name used in reports and CLI arguments.
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::InSitu => "insitu",
            Strategy::NaivePingPong => "naive",
            Strategy::IntraMacroPingPong => "intra",
            Strategy::GeneralizedPingPong => "gpp",
        }
    }

    /// Parse a CLI name.
    pub fn from_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "insitu" | "in-situ" | "in_situ" => Some(Strategy::InSitu),
            "naive" | "pingpong" | "ping-pong" | "naive-pingpong" => Some(Strategy::NaivePingPong),
            "intra" | "intra-macro" | "intra-pingpong" => Some(Strategy::IntraMacroPingPong),
            "gpp" | "generalized" | "generalized-pingpong" => Some(Strategy::GeneralizedPingPong),
            _ => None,
        }
    }

    /// True if the strategy needs macros that can write one partition
    /// while computing on the other ([`crate::sim::SimOptions::allow_intra_overlap`]).
    pub fn requires_intra_overlap(&self) -> bool {
        matches!(self, Strategy::IntraMacroPingPong)
    }

    /// Simulator options appropriate for this strategy.
    pub fn sim_options(&self) -> crate::sim::SimOptions {
        crate::sim::SimOptions {
            allow_intra_overlap: self.requires_intra_overlap(),
            ..crate::sim::SimOptions::default()
        }
    }

    /// Generate the program implementing this strategy for `plan`
    /// (unrolled — see [`Strategy::codegen_styled`]).
    pub fn codegen(&self, arch: &ArchConfig, plan: &SchedulePlan) -> Result<Program, ScheduleError> {
        self.codegen_styled(arch, plan, CodegenStyle::Unrolled)
    }

    /// Generate the program in the requested [`CodegenStyle`].
    ///
    /// The looped form exists for `insitu`, `naive` and `gpp` (their
    /// steady states are per-core/per-stream periods — naive's is the
    /// 2-phase bank period); `intra` falls back to the unrolled form,
    /// which is timing-identical by definition.
    pub fn codegen_styled(
        &self,
        arch: &ArchConfig,
        plan: &SchedulePlan,
        style: CodegenStyle,
    ) -> Result<Program, ScheduleError> {
        plan.check(arch)?;
        Ok(match (self, style) {
            (Strategy::InSitu, CodegenStyle::Unrolled) => insitu::codegen(arch, plan),
            (Strategy::InSitu, CodegenStyle::Looped) => insitu::codegen_looped(arch, plan),
            (Strategy::NaivePingPong, CodegenStyle::Unrolled) => naive::codegen(arch, plan),
            (Strategy::NaivePingPong, CodegenStyle::Looped) => naive::codegen_looped(arch, plan),
            (Strategy::IntraMacroPingPong, _) => intra::codegen(arch, plan),
            (Strategy::GeneralizedPingPong, CodegenStyle::Unrolled) => {
                generalized::codegen(arch, plan)
            }
            (Strategy::GeneralizedPingPong, CodegenStyle::Looped) => {
                generalized::codegen_looped(arch, plan)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for s in Strategy::ALL_EXTENDED {
            assert_eq!(Strategy::from_name(s.name()), Some(s));
        }
        assert_eq!(Strategy::from_name("nope"), None);
    }

    #[test]
    fn only_intra_needs_overlap() {
        for s in Strategy::ALL {
            assert!(!s.requires_intra_overlap());
            assert!(!s.sim_options().allow_intra_overlap);
        }
        assert!(Strategy::IntraMacroPingPong.requires_intra_overlap());
        assert!(Strategy::IntraMacroPingPong.sim_options().allow_intra_overlap);
    }

    #[test]
    fn aliases_accepted() {
        assert_eq!(Strategy::from_name("in-situ"), Some(Strategy::InSitu));
        assert_eq!(
            Strategy::from_name("ping-pong"),
            Some(Strategy::NaivePingPong)
        );
        assert_eq!(
            Strategy::from_name("GENERALIZED"),
            Some(Strategy::GeneralizedPingPong)
        );
    }
}

//! Seeded single-defect mutations of known-good programs — the
//! differential oracle that the static verifier has teeth.
//!
//! Each [`MutationClass`] injects one representative schedule defect into
//! a lowered program (on a clone; the input is untouched): dropping a
//! `waitw` creates a write/compute hazard, swapping a `vmm` tile breaks
//! the tile contract, removing an `endloop` breaks structure, oversizing
//! an `ldin` blows the core buffer, and removing a `barrier` desynchronizes
//! the phase structure.  `analysis` unit tests and the CI verify smoke
//! assert every class is *caught with a located diagnostic* on every
//! applicable strategy × style lowering.
//!
//! Site selection is seeded ([`crate::util::rng::XorShift64`]) so a CI
//! failure reproduces exactly from the reported seed.

use crate::isa::{Inst, Program};
use crate::util::rng::XorShift64;

/// One class of injected schedule defect.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MutationClass {
    /// Remove a live `waitw` that guards a later `vmm` on the same macro
    /// — caught as a compute-during-write hazard (or a tile-unknown
    /// mismatch under intra-macro overlap).
    DropWaitW,
    /// Re-target a `vmm` to a tile that was never written — caught as a
    /// tile mismatch.
    SwapTile,
    /// Remove an `endloop` — caught as unbalanced loop nesting.
    UnbalanceLoop,
    /// Inflate an `ldin` to `u16::MAX` vectors — caught as a core buffer
    /// overflow (any realistic `core_buffer_bytes` is below the ~2 MiB
    /// this injects).
    OversizeLdIn,
    /// Remove one `barrier` from one stream of a multi-stream program —
    /// caught as a loop-weighted barrier count mismatch.
    DropBarrier,
}

impl MutationClass {
    /// Every mutation class, in a stable order.
    pub const ALL: [MutationClass; 5] = [
        MutationClass::DropWaitW,
        MutationClass::SwapTile,
        MutationClass::UnbalanceLoop,
        MutationClass::OversizeLdIn,
        MutationClass::DropBarrier,
    ];

    /// Stable CLI/spec name.
    pub fn name(&self) -> &'static str {
        match self {
            MutationClass::DropWaitW => "drop-waitw",
            MutationClass::SwapTile => "swap-tile",
            MutationClass::UnbalanceLoop => "unbalance-loop",
            MutationClass::OversizeLdIn => "oversize-ldin",
            MutationClass::DropBarrier => "drop-barrier",
        }
    }

    /// Parse a CLI/spec name.
    pub fn from_name(name: &str) -> Option<Self> {
        MutationClass::ALL
            .into_iter()
            .find(|c| c.name() == name.to_ascii_lowercase())
    }
}

/// The tile offset a [`MutationClass::SwapTile`] mutation adds — far
/// beyond any real `tile_id`, so the swapped tile never aliases one.
const SWAP_TILE_OFFSET: u32 = 1_000_000;

/// Apply one seeded mutation of `class` to a clone of `program`.
///
/// Returns `None` when the class has no applicable site (e.g. no loops
/// in an unrolled lowering, no barriers in a barrier-free strategy, or a
/// single-stream program for [`MutationClass::DropBarrier`]).
pub fn mutate(program: &Program, class: MutationClass, seed: u64) -> Option<Program> {
    let sites = candidate_sites(program, class);
    if sites.is_empty() {
        return None;
    }
    let mut rng = XorShift64::new(seed);
    let (si, at) = sites[rng.next_below(sites.len() as u64) as usize];
    let mut mutated = program.clone();
    let insts = &mut mutated.streams[si].insts;
    match class {
        MutationClass::DropWaitW | MutationClass::UnbalanceLoop | MutationClass::DropBarrier => {
            insts.remove(at);
        }
        MutationClass::SwapTile => {
            if let Inst::Vmm { tile, .. } = &mut insts[at] {
                *tile += SWAP_TILE_OFFSET;
            }
        }
        MutationClass::OversizeLdIn => {
            if let Inst::LdIn { n_vec } = &mut insts[at] {
                *n_vec = u16::MAX;
            }
        }
    }
    Some(mutated)
}

/// All `(stream, offset)` sites where `class` can be injected such that
/// the defect is observable.
fn candidate_sites(program: &Program, class: MutationClass) -> Vec<(usize, usize)> {
    let mut sites = Vec::new();
    // DropBarrier is only observable when another stream keeps its count.
    let barrier_streams = program
        .streams
        .iter()
        .filter(|s| s.insts.iter().any(|i| matches!(i, Inst::Barrier)))
        .count();
    for (si, stream) in program.streams.iter().enumerate() {
        for (at, inst) in stream.insts.iter().enumerate() {
            let applicable = match (class, inst) {
                (MutationClass::DropWaitW, Inst::WaitW { m }) => {
                    let wrote_before = stream.insts[..at]
                        .iter()
                        .any(|i| matches!(i, Inst::Wrw { m: wm, .. } if wm == m));
                    let computes_after = stream.insts[at + 1..]
                        .iter()
                        .any(|i| matches!(i, Inst::Vmm { m: vm, .. } if vm == m));
                    wrote_before && computes_after
                }
                (MutationClass::SwapTile, Inst::Vmm { .. }) => true,
                (MutationClass::UnbalanceLoop, Inst::EndLoop) => true,
                (MutationClass::OversizeLdIn, Inst::LdIn { .. }) => true,
                (MutationClass::DropBarrier, Inst::Barrier) => barrier_streams >= 2,
                _ => false,
            };
            if applicable {
                sites.push((si, at));
            }
        }
    }
    sites
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{verify_program, VerifyOptions};
    use crate::arch::ArchConfig;
    use crate::sched::{CodegenStyle, SchedulePlan, Strategy};

    fn grid() -> Vec<(Strategy, CodegenStyle, Program, ArchConfig)> {
        let mut cells = Vec::new();
        for arch in [ArchConfig::paper_default(), ArchConfig::fig4_default()] {
            let plan = SchedulePlan {
                tasks: 24,
                active_macros: 8,
                n_in: arch.n_in,
                write_speed: arch.write_speed,
            };
            for strategy in Strategy::ALL_EXTENDED {
                for style in [CodegenStyle::Unrolled, CodegenStyle::Looped] {
                    let program = strategy.codegen_styled(&arch, &plan, style).unwrap();
                    cells.push((strategy, style, program, arch.clone()));
                }
            }
        }
        cells
    }

    #[test]
    fn every_class_is_caught_on_every_applicable_lowering() {
        for class in MutationClass::ALL {
            let mut applied = 0usize;
            for (strategy, style, program, arch) in grid() {
                let Some(mutated) = mutate(&program, class, 7) else {
                    continue;
                };
                applied += 1;
                let report =
                    verify_program(&arch, &mutated, &VerifyOptions::for_strategy(strategy));
                assert!(
                    !report.ok(),
                    "{class:?} on {strategy:?}/{style:?} was not caught"
                );
                // The diagnostic is located: its Display names a stream
                // offset or a stream id.
                let text = report.first_error().unwrap().to_string();
                assert!(
                    text.contains('@') || text.contains("stream"),
                    "unlocated diagnostic: {text}"
                );
            }
            assert!(applied >= 1, "{class:?} applied to no lowering");
        }
    }

    #[test]
    fn pristine_programs_stay_clean() {
        for (strategy, style, program, arch) in grid() {
            let report = verify_program(&arch, &program, &VerifyOptions::for_strategy(strategy));
            assert!(report.ok(), "{strategy:?}/{style:?} not clean pre-mutation");
        }
    }

    #[test]
    fn mutation_is_deterministic_in_seed() {
        let arch = ArchConfig::paper_default();
        let plan = SchedulePlan::full_chip(&arch, 32);
        let program = Strategy::NaivePingPong.codegen(&arch, &plan).unwrap();
        let a = mutate(&program, MutationClass::SwapTile, 7).unwrap();
        let b = mutate(&program, MutationClass::SwapTile, 7).unwrap();
        let c = mutate(&program, MutationClass::SwapTile, 8).unwrap();
        assert_eq!(a, b);
        // Different seeds may pick the same site; at minimum the result
        // is still a single-defect program differing from the original.
        assert_ne!(a, program);
        assert_ne!(c, program);
    }

    #[test]
    fn inapplicable_classes_return_none() {
        let arch = ArchConfig::paper_default();
        let plan = SchedulePlan::full_chip(&arch, 16);
        // Unrolled GPP has no loops and no barriers.
        let program = Strategy::GeneralizedPingPong.codegen(&arch, &plan).unwrap();
        assert!(mutate(&program, MutationClass::UnbalanceLoop, 7).is_none());
        assert!(mutate(&program, MutationClass::DropBarrier, 7).is_none());
    }

    #[test]
    fn names_roundtrip() {
        for class in MutationClass::ALL {
            assert_eq!(MutationClass::from_name(class.name()), Some(class));
        }
        assert_eq!(MutationClass::from_name("bogus"), None);
    }
}

//! Static schedule verification: prove ping-pong safety without running
//! the simulator.
//!
//! The paper's pipelining argument rests on invariants the lowered
//! programs must uphold — a macro never computes on a tile whose rewrite
//! is still in flight, core buffers never overflow, and the barrier/wait
//! structure cannot deadlock.  The cycle-exact engine *exercises* these
//! dynamically (a violation surfaces as a `SimError` mid-run), but a
//! codegen bug can also surface as silently-wrong `SimStats`.  This
//! module proves the invariants by abstract interpretation over
//! [`Program`] — per core, per macro, with a loop-body fixpoint — and
//! certifies an analytic lower bound (write-traffic bound ⊔ per-macro
//! busy-time bound, reusing [`crate::model::eqs`]) that simulated cycles
//! must respect.
//!
//! Checked properties:
//!
//! 1. **Hazard freedom** — no `vmm` on a macro with an un-`waitw`ed
//!    `wrw` in flight, `vmm.tile` matches the last committed `wrw.tile`,
//!    no double-issue of `wrw`/`vmm` to a busy macro, `setspd` within the
//!    hardware range.  Mirrors the engine's `SimError` hazard checks.
//! 2. **Buffer bounds** — the `ldin`/`vmm`/`stout` occupancy interval of
//!    every core stays within `core_buffer_bytes` (sum of per-stream
//!    peaks: streams of one core interleave arbitrarily), and never goes
//!    negative.  Loop bodies use an exact closed form over the iteration
//!    count; a non-zero per-iteration occupancy delta is flagged as a
//!    drift warning.
//! 3. **Structural liveness** — balanced `loop`/`endloop`, non-zero loop
//!    counts, a trailing `halt`, macro/core ids in range, loop-weighted
//!    `barrier` counts equal across all streams (a mismatch breaks the
//!    phase intent even though halted streams release engine barriers),
//!    and each macro driven by a single stream.  A wait with nothing in
//!    flight is a *warning* (dead wait = latent perf bug, not unsafe).
//! 4. **Analytic lower bound** — `max(write-traffic bound, max per-macro
//!    busy time)`; [`VerifyReport::certify_cycles`] turns a simulated
//!    cycle count below the bound into a hard error.
//!
//! The differential oracle that the verifier has teeth lives in
//! [`mutate`]: seeded single-defect mutations of known-good programs,
//! each class asserted to be caught with a located diagnostic.

pub mod mutate;

pub use mutate::MutationClass;

use crate::arch::ArchConfig;
use crate::isa::{Inst, Program};
use crate::model::eqs;
use crate::sched::Strategy;
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::fmt;
use thiserror::Error;

/// Maximum loop-body iterations the hazard fixpoint runs before giving
/// up; every shipped lowering stabilizes after 2.
const FIXPOINT_CAP: usize = 4;

/// Location of a diagnostic: core, stream, instruction offset, mnemonic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Site {
    /// Stream index within the program.
    pub stream: usize,
    /// Core the stream addresses.
    pub core: u32,
    /// Instruction offset within the stream.
    pub at: usize,
    /// Mnemonic of the instruction at the offset.
    pub mnemonic: &'static str,
}

impl fmt::Display for Site {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "core {} stream {} @{} ({})",
            self.core, self.stream, self.at, self.mnemonic
        )
    }
}

/// A proven-unsafe schedule property.  Tile id 0 means "no tile loaded".
#[derive(Debug, Error, Clone, PartialEq, Eq)]
pub enum VerifyError {
    #[error("{site}: wrw to macro {m} while a write is already in flight")]
    DoubleWrite { site: Site, m: u8 },
    #[error("{site}: vmm on macro {m} while a compute is already in flight")]
    DoubleCompute { site: Site, m: u8 },
    #[error("{site}: wrw to macro {m} while it is computing (no intra-macro overlap)")]
    WriteDuringCompute { site: Site, m: u8 },
    #[error("{site}: vmm on macro {m} while its weight rewrite is in flight")]
    ComputeDuringWrite { site: Site, m: u8 },
    #[error("{site}: vmm wants tile {want} but macro {m} holds tile {have} (0 = none)")]
    WrongTile { site: Site, m: u8, want: u32, have: u32 },
    #[error("{site}: setspd {speed} outside hardware range [{min}, {max}]")]
    SpeedOutOfRange {
        site: Site,
        speed: u16,
        min: u32,
        max: u32,
    },
    #[error("{site}: macro {m} out of range (cores have {max} macros)")]
    MacroOutOfRange { site: Site, m: u8, max: u32 },
    #[error("{site}: core buffers need {need} B at peak but the core has {have} B")]
    BufferOverflow { site: Site, need: u64, have: u64 },
    #[error("{site}: buffer occupancy would fall to {occupancy} B (stout exceeds prior ldin/vmm)")]
    BufferUnderflow { site: Site, occupancy: i64 },
    #[error("{site}: unbalanced loop/endloop nesting")]
    UnbalancedLoop { site: Site },
    #[error("{site}: loop has zero iteration count")]
    ZeroLoop { site: Site },
    #[error("core {core} stream {stream}: program does not end with halt")]
    MissingHalt { core: u32, stream: usize },
    #[error("stream {stream} targets core {core} but the chip has {n_cores} cores")]
    CoreOutOfRange {
        stream: usize,
        core: u32,
        n_cores: u32,
    },
    #[error(
        "core {core} stream {stream}: executes {count} barriers but stream 0 executes {expect}"
    )]
    BarrierMismatch {
        core: u32,
        stream: usize,
        count: u64,
        expect: u64,
    },
    #[error("core {core} macro {m}: driven by streams {a} and {b} (one owner per macro)")]
    SharedMacro { core: u32, m: u8, a: usize, b: usize },
    #[error("analytic lower bound {bound} cycles exceeds simulated {simulated} cycles")]
    BoundViolation { bound: u64, simulated: u64 },
}

/// A latent inefficiency or an analysis limit — the schedule is still
/// safe to run.
#[derive(Debug, Error, Clone, PartialEq, Eq)]
pub enum VerifyWarning {
    #[error("{site}: wait on macro {m} with nothing in flight (dead wait)")]
    DeadWait { site: Site, m: u8 },
    #[error("{site}: loop body shifts buffer occupancy by {delta} B per iteration")]
    LoopOccupancyDrift { site: Site, delta: i64 },
    #[error("{site}: hazard state did not stabilize across loop iterations")]
    LoopStateUnstable { site: Site },
    #[error("{site}: macro {m} still busy at halt")]
    InFlightAtHalt { site: Site, m: u8 },
}

/// Analysis knobs, mirroring the engine options that change legality.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct VerifyOptions {
    /// Macros may overlap a partition write with a compute on the other
    /// partition ([`crate::sim::SimOptions::allow_intra_overlap`]).
    pub allow_intra_overlap: bool,
}

impl VerifyOptions {
    /// The options matching how [`Strategy`] programs are simulated
    /// ([`Strategy::sim_options`]).
    pub fn for_strategy(strategy: Strategy) -> Self {
        Self {
            allow_intra_overlap: strategy.requires_intra_overlap(),
        }
    }
}

/// The verifier's verdict over one program.
#[derive(Debug, Clone, Default)]
pub struct VerifyReport {
    /// Proven violations — the program is unsafe to trust.
    pub errors: Vec<VerifyError>,
    /// Latent inefficiencies; the program is still safe.
    pub warnings: Vec<VerifyWarning>,
    /// Analytic lower bound on execution cycles (0 for an empty program).
    pub lower_bound_cycles: u64,
    /// Streams analyzed.
    pub streams: usize,
    /// Total instructions analyzed.
    pub insts: usize,
}

impl VerifyReport {
    /// True when no errors were found (warnings allowed).
    pub fn ok(&self) -> bool {
        self.errors.is_empty()
    }

    /// The first error, if any.
    pub fn first_error(&self) -> Option<&VerifyError> {
        self.errors.first()
    }

    /// Certify a simulated cycle count against the analytic lower bound:
    /// pushes a [`VerifyError::BoundViolation`] and returns false when
    /// the simulation claims to beat the bound.
    pub fn certify_cycles(&mut self, simulated: u64) -> bool {
        if self.lower_bound_cycles > simulated {
            self.errors.push(VerifyError::BoundViolation {
                bound: self.lower_bound_cycles,
                simulated,
            });
            return false;
        }
        true
    }
}

/// Verify `program` against `arch` without simulating it.
pub fn verify_program(arch: &ArchConfig, program: &Program, opts: &VerifyOptions) -> VerifyReport {
    let mut v = Verifier {
        arch,
        opts: *opts,
        errors: Vec::new(),
        warnings: Vec::new(),
    };
    v.run(program);
    VerifyReport {
        lower_bound_cycles: v.lower_bound(program),
        streams: program.streams.len(),
        insts: program.streams.iter().map(|s| s.insts.len()).sum(),
        errors: v.errors,
        warnings: v.warnings,
    }
}

/// Abstract per-macro state: what is in flight and which tile the macro
/// holds (0 = none/unknown — tile ids are 1-based by construction).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct MacroState {
    write_busy: bool,
    pending: u32,
    compute_busy: bool,
    loaded: u32,
}

/// Abstract per-stream state for the hazard automaton.
#[derive(Debug, Clone, PartialEq, Eq)]
struct StreamState {
    speed: u32,
    macros: BTreeMap<u8, MacroState>,
}

/// Buffer-occupancy summary of an instruction range: net delta plus the
/// min/max prefix (and the offsets attaining them, for diagnostics).
#[derive(Debug, Clone, Copy)]
struct Seg {
    net: i64,
    min: i64,
    min_at: usize,
    max: i64,
    max_at: usize,
}

impl Seg {
    fn empty(at: usize) -> Self {
        Seg {
            net: 0,
            min: 0,
            min_at: at,
            max: 0,
            max_at: at,
        }
    }

    /// Sequential composition: `self` then `b`.
    fn then(self, b: Seg) -> Seg {
        let (min, min_at) = if self.net.saturating_add(b.min) < self.min {
            (self.net.saturating_add(b.min), b.min_at)
        } else {
            (self.min, self.min_at)
        };
        let (max, max_at) = if self.net.saturating_add(b.max) > self.max {
            (self.net.saturating_add(b.max), b.max_at)
        } else {
            (self.max, self.max_at)
        };
        Seg {
            net: self.net.saturating_add(b.net),
            min,
            min_at,
            max,
            max_at,
        }
    }

    /// Exact closed form for `k` sequential repetitions of `self`.
    fn repeat(self, k: u32) -> Seg {
        let k = k.max(1);
        let shift = self.net.saturating_mul(k as i64 - 1);
        let (min, min_at) = if self.net < 0 {
            (shift.saturating_add(self.min), self.min_at)
        } else {
            (self.min, self.min_at)
        };
        let (max, max_at) = if self.net > 0 {
            (shift.saturating_add(self.max), self.max_at)
        } else {
            (self.max, self.max_at)
        };
        Seg {
            net: self.net.saturating_mul(k as i64),
            min,
            min_at,
            max,
            max_at,
        }
    }
}

/// Loop-weighted write/compute busy cycles of one macro.
#[derive(Debug, Clone, Copy, Default)]
struct MacroTally {
    compute: u64,
    write: u64,
}

struct Verifier<'a> {
    arch: &'a ArchConfig,
    opts: VerifyOptions,
    errors: Vec<VerifyError>,
    warnings: Vec<VerifyWarning>,
}

impl Verifier<'_> {
    fn err(&mut self, e: VerifyError) {
        if !self.errors.contains(&e) {
            self.errors.push(e);
        }
    }

    fn warn(&mut self, w: VerifyWarning) {
        if !self.warnings.contains(&w) {
            self.warnings.push(w);
        }
    }

    fn site(&self, program: &Program, si: usize, at: usize) -> Site {
        let stream = &program.streams[si];
        Site {
            stream: si,
            core: stream.core,
            at,
            mnemonic: stream.insts.get(at).map_or("halt", Inst::mnemonic),
        }
    }

    fn run(&mut self, program: &Program) {
        // --- structural pass: builds the Loop -> EndLoop match map and
        // marks streams whose control flow is too broken to walk.
        let mut match_of: Vec<HashMap<usize, usize>> = Vec::with_capacity(program.streams.len());
        let mut walkable: Vec<bool> = Vec::with_capacity(program.streams.len());
        for (si, stream) in program.streams.iter().enumerate() {
            if stream.core >= program.n_cores || stream.core >= self.arch.n_cores {
                self.err(VerifyError::CoreOutOfRange {
                    stream: si,
                    core: stream.core,
                    n_cores: program.n_cores.min(self.arch.n_cores),
                });
            }
            let mut matches = HashMap::new();
            let mut stack: Vec<usize> = Vec::new();
            let mut balanced = true;
            for (at, inst) in stream.insts.iter().enumerate() {
                match inst {
                    Inst::Loop { count } => {
                        if *count == 0 {
                            self.err(VerifyError::ZeroLoop {
                                site: self.site(program, si, at),
                            });
                        }
                        stack.push(at);
                    }
                    Inst::EndLoop => {
                        if let Some(open) = stack.pop() {
                            matches.insert(open, at);
                        } else {
                            self.err(VerifyError::UnbalancedLoop {
                                site: self.site(program, si, at),
                            });
                            balanced = false;
                        }
                    }
                    Inst::Wrw { m, .. }
                    | Inst::Vmm { m, .. }
                    | Inst::WaitW { m }
                    | Inst::WaitC { m } => {
                        if *m as u32 >= self.arch.macros_per_core {
                            self.err(VerifyError::MacroOutOfRange {
                                site: self.site(program, si, at),
                                m: *m,
                                max: self.arch.macros_per_core,
                            });
                        }
                    }
                    _ => {}
                }
            }
            if let Some(&open) = stack.last() {
                self.err(VerifyError::UnbalancedLoop {
                    site: self.site(program, si, open),
                });
                balanced = false;
            }
            if !matches!(stream.insts.last(), Some(Inst::Halt)) {
                self.err(VerifyError::MissingHalt {
                    core: stream.core,
                    stream: si,
                });
            }
            match_of.push(matches);
            walkable.push(balanced);
        }

        // --- barrier alignment: loop-weighted barrier counts must agree
        // across every stream (halted streams do release engine barriers,
        // but a mismatch means whole phases run against the wrong bank).
        let counts: Vec<Option<u64>> = program
            .streams
            .iter()
            .enumerate()
            .map(|(si, s)| {
                walkable[si].then(|| weighted_barriers(&s.insts, 0, s.insts.len(), &match_of[si]))
            })
            .collect();
        if let Some(expect) = counts.iter().flatten().next().copied() {
            for (si, count) in counts.iter().enumerate() {
                if let Some(count) = *count {
                    if count != expect {
                        self.err(VerifyError::BarrierMismatch {
                            core: program.streams[si].core,
                            stream: si,
                            count,
                            expect,
                        });
                    }
                }
            }
        }

        // --- macro ownership: the hazard automaton is per-stream, which
        // is sound only while each (core, macro) is driven by one stream.
        let mut owner: BTreeMap<(u32, u8), usize> = BTreeMap::new();
        for (si, stream) in program.streams.iter().enumerate() {
            for inst in &stream.insts {
                let m = match inst {
                    Inst::Wrw { m, .. }
                    | Inst::Vmm { m, .. }
                    | Inst::WaitW { m }
                    | Inst::WaitC { m } => *m,
                    _ => continue,
                };
                let key = (stream.core, m);
                match owner.get(&key) {
                    None => {
                        owner.insert(key, si);
                    }
                    Some(&a) if a != si => {
                        self.err(VerifyError::SharedMacro {
                            core: stream.core,
                            m,
                            a,
                            b: si,
                        });
                    }
                    _ => {}
                }
            }
        }

        // --- hazard automaton per walkable stream.
        for (si, stream) in program.streams.iter().enumerate() {
            if !walkable[si] {
                continue;
            }
            let mut state = StreamState {
                speed: self.arch.write_speed,
                macros: BTreeMap::new(),
            };
            self.exec_range(program, si, 0, stream.insts.len(), &match_of[si], &mut state);
            let halt_at = stream.insts.len().saturating_sub(1);
            for (&m, ms) in &state.macros {
                if ms.write_busy || ms.compute_busy {
                    self.warn(VerifyWarning::InFlightAtHalt {
                        site: self.site(program, si, halt_at),
                        m,
                    });
                }
            }
        }

        // --- buffer bounds: per-stream occupancy envelope, summed per
        // core (streams of one core interleave arbitrarily, so the core
        // peak is bounded by the sum of stream peaks — exactly the
        // feasibility bound `SchedulePlan::check` enforces).
        let mut core_need: BTreeMap<u32, (u64, i64, usize)> = BTreeMap::new(); // core -> (sum, worst max, worst stream)
        for (si, stream) in program.streams.iter().enumerate() {
            if !walkable[si] {
                continue;
            }
            let seg = self.seg_range(program, si, 0, stream.insts.len(), &match_of[si]);
            if seg.min < 0 {
                self.err(VerifyError::BufferUnderflow {
                    site: self.site(program, si, seg.min_at),
                    occupancy: seg.min,
                });
            }
            let peak = seg.max.max(0) as u64;
            let entry = core_need.entry(stream.core).or_insert((0, -1, si));
            entry.0 = entry.0.saturating_add(peak);
            if seg.max > entry.1 {
                entry.1 = seg.max;
                entry.2 = si;
            }
        }
        for (_core, (need, _, worst_si)) in core_need {
            if need > self.arch.core_buffer_bytes {
                let stream = &program.streams[worst_si];
                let seg = self.seg_range(
                    program,
                    worst_si,
                    0,
                    stream.insts.len(),
                    &match_of[worst_si],
                );
                self.err(VerifyError::BufferOverflow {
                    site: self.site(program, worst_si, seg.max_at),
                    need,
                    have: self.arch.core_buffer_bytes,
                });
            }
        }
    }

    /// Interpret `insts[start..end]` of stream `si` over the hazard state.
    fn exec_range(
        &mut self,
        program: &Program,
        si: usize,
        start: usize,
        end: usize,
        match_of: &HashMap<usize, usize>,
        state: &mut StreamState,
    ) {
        let insts = &program.streams[si].insts;
        let allow_intra = self.opts.allow_intra_overlap;
        let mut i = start;
        while i < end {
            match insts[i] {
                Inst::SetSpd { speed } => {
                    if (speed as u32) < self.arch.min_write_speed
                        || speed as u32 > self.arch.max_write_speed
                    {
                        self.err(VerifyError::SpeedOutOfRange {
                            site: self.site(program, si, i),
                            speed,
                            min: self.arch.min_write_speed,
                            max: self.arch.max_write_speed,
                        });
                    }
                    state.speed = (speed as u32).max(1);
                }
                Inst::Wrw { m, tile } => {
                    let site = self.site(program, si, i);
                    let prev = *state.macros.entry(m).or_default();
                    if prev.write_busy {
                        self.err(VerifyError::DoubleWrite { site, m });
                    } else if prev.compute_busy && !allow_intra {
                        self.err(VerifyError::WriteDuringCompute { site, m });
                    }
                    let ms = state.macros.entry(m).or_default();
                    ms.write_busy = true;
                    ms.pending = tile;
                    ms.loaded = 0;
                }
                Inst::WaitW { m } => {
                    let site = self.site(program, si, i);
                    let prev = *state.macros.entry(m).or_default();
                    if !prev.write_busy {
                        self.warn(VerifyWarning::DeadWait { site, m });
                    } else {
                        let ms = state.macros.entry(m).or_default();
                        ms.write_busy = false;
                        ms.loaded = ms.pending;
                    }
                }
                Inst::Vmm { m, tile, .. } => {
                    let site = self.site(program, si, i);
                    let ms = *state.macros.entry(m).or_default();
                    if ms.compute_busy {
                        self.err(VerifyError::DoubleCompute { site, m });
                    }
                    if ms.write_busy && !allow_intra {
                        self.err(VerifyError::ComputeDuringWrite { site, m });
                    } else {
                        // With an in-flight write (intra-overlap mode) the
                        // macro contents are statically unknown: the engine
                        // only publishes the tile at write *completion*.
                        let have = if ms.write_busy { 0 } else { ms.loaded };
                        if have != tile {
                            self.err(VerifyError::WrongTile {
                                site,
                                m,
                                want: tile,
                                have,
                            });
                        }
                    }
                    state.macros.entry(m).or_default().compute_busy = true;
                }
                Inst::WaitC { m } => {
                    let site = self.site(program, si, i);
                    let prev = *state.macros.entry(m).or_default();
                    if !prev.compute_busy {
                        self.warn(VerifyWarning::DeadWait { site, m });
                    } else {
                        state.macros.entry(m).or_default().compute_busy = false;
                    }
                }
                Inst::Loop { count } => {
                    if let Some(&close) = match_of.get(&i) {
                        let cap = (count.max(1) as usize).min(FIXPOINT_CAP);
                        let mut stable = false;
                        for _ in 0..cap {
                            let prev = state.clone();
                            self.exec_range(program, si, i + 1, close, match_of, state);
                            if *state == prev {
                                stable = true;
                                break;
                            }
                        }
                        if !stable && count as usize > FIXPOINT_CAP {
                            self.warn(VerifyWarning::LoopStateUnstable {
                                site: self.site(program, si, i),
                            });
                        }
                        i = close;
                    }
                }
                Inst::Halt => return,
                Inst::Delay { .. }
                | Inst::LdIn { .. }
                | Inst::StOut { .. }
                | Inst::Barrier
                | Inst::EndLoop => {}
            }
            i += 1;
        }
    }

    /// Buffer-occupancy envelope of `insts[start..end]` of stream `si`.
    fn seg_range(
        &mut self,
        program: &Program,
        si: usize,
        start: usize,
        end: usize,
        match_of: &HashMap<usize, usize>,
    ) -> Seg {
        let insts = &program.streams[si].insts;
        let rows = self.arch.geom.rows as i64;
        let cols = self.arch.geom.cols as i64;
        let mut acc = Seg::empty(start);
        let mut i = start;
        while i < end {
            match insts[i] {
                Inst::LdIn { n_vec } => {
                    acc = acc.then(delta_seg(n_vec as i64 * rows, i));
                }
                Inst::Vmm { n_vec, .. } => {
                    acc = acc.then(delta_seg(n_vec as i64 * 4 * cols, i));
                }
                Inst::StOut { n_vec } => {
                    acc = acc.then(delta_seg(-(n_vec as i64 * (rows + 4 * cols)), i));
                }
                Inst::Loop { count } => {
                    if let Some(&close) = match_of.get(&i) {
                        let body = self.seg_range(program, si, i + 1, close, match_of);
                        if body.net != 0 {
                            self.warn(VerifyWarning::LoopOccupancyDrift {
                                site: self.site(program, si, i),
                                delta: body.net,
                            });
                        }
                        acc = acc.then(body.repeat(count));
                        i = close;
                    }
                }
                Inst::Halt => return acc,
                _ => {}
            }
            i += 1;
        }
        acc
    }

    /// The analytic lower bound on execution cycles: write traffic must
    /// cross the off-chip bus (`min(writers × s_max, band.)` B/cycle at
    /// best — [`eqs::weight_write_cycles`]), and no macro can finish
    /// before its own loop-weighted busy time elapses.
    fn lower_bound(&self, program: &Program) -> u64 {
        let mut per_macro: BTreeMap<(u32, u8), MacroTally> = BTreeMap::new();
        let mut writers: BTreeSet<(u32, u8)> = BTreeSet::new();
        let mut total_bytes = 0u64;
        let mut max_speed = 0u32;
        for stream in &program.streams {
            // Rebuild the match map; unmatched loops are simply skipped
            // (the structural pass already reported them).
            let mut matches = HashMap::new();
            let mut stack = Vec::new();
            for (at, inst) in stream.insts.iter().enumerate() {
                match inst {
                    Inst::Loop { .. } => stack.push(at),
                    Inst::EndLoop => {
                        if let Some(open) = stack.pop() {
                            matches.insert(open, at);
                        }
                    }
                    _ => {}
                }
            }
            let mut speed = self.arch.write_speed;
            tally_range(
                self.arch,
                stream,
                0,
                stream.insts.len(),
                &matches,
                1,
                &mut speed,
                &mut per_macro,
                &mut writers,
                &mut total_bytes,
                &mut max_speed,
            );
        }
        let write_bound = if total_bytes > 0 {
            eqs::weight_write_cycles(
                total_bytes,
                writers.len().max(1) as u64,
                max_speed.max(1) as u64,
                self.arch.bandwidth,
            )
        } else {
            0
        };
        let macro_bound = per_macro
            .values()
            .map(|t| {
                if self.opts.allow_intra_overlap {
                    t.compute.max(t.write)
                } else {
                    t.compute.saturating_add(t.write)
                }
            })
            .max()
            .unwrap_or(0);
        write_bound.max(macro_bound)
    }
}

fn delta_seg(d: i64, at: usize) -> Seg {
    Seg {
        net: d,
        min: d.min(0),
        min_at: at,
        max: d.max(0),
        max_at: at,
    }
}

/// Loop-weighted barrier count of `insts[start..end]`.
fn weighted_barriers(
    insts: &[Inst],
    start: usize,
    end: usize,
    match_of: &HashMap<usize, usize>,
) -> u64 {
    let mut total = 0u64;
    let mut i = start;
    while i < end {
        match insts[i] {
            Inst::Barrier => total = total.saturating_add(1),
            Inst::Loop { count } => {
                if let Some(&close) = match_of.get(&i) {
                    let body = weighted_barriers(insts, i + 1, close, match_of);
                    total = total.saturating_add(body.saturating_mul(count as u64));
                    i = close;
                }
            }
            Inst::Halt => return total,
            _ => {}
        }
        i += 1;
    }
    total
}

/// Accumulate loop-weighted write/compute busy cycles for the lower
/// bound.  `mult` is the product of enclosing loop counts.
#[allow(clippy::too_many_arguments)]
fn tally_range(
    arch: &ArchConfig,
    stream: &crate::isa::Stream,
    start: usize,
    end: usize,
    match_of: &HashMap<usize, usize>,
    mult: u64,
    speed: &mut u32,
    per_macro: &mut BTreeMap<(u32, u8), MacroTally>,
    writers: &mut BTreeSet<(u32, u8)>,
    total_bytes: &mut u64,
    max_speed: &mut u32,
) {
    let mut i = start;
    while i < end {
        match stream.insts[i] {
            Inst::SetSpd { speed: s } => *speed = (s as u32).max(1),
            Inst::Wrw { m, .. } => {
                let key = (stream.core, m);
                writers.insert(key);
                *total_bytes = total_bytes.saturating_add(mult.saturating_mul(arch.geom.size_macro()));
                *max_speed = (*max_speed).max(*speed);
                let t = per_macro.entry(key).or_default();
                t.write = t
                    .write
                    .saturating_add(mult.saturating_mul(arch.time_rewrite_at(*speed)));
            }
            Inst::Vmm { m, n_vec, .. } => {
                let t = per_macro.entry((stream.core, m)).or_default();
                t.compute = t.compute.saturating_add(
                    mult.saturating_mul(arch.geom.cycles_per_vector() * n_vec as u64),
                );
            }
            Inst::Loop { count } => {
                if let Some(&close) = match_of.get(&i) {
                    tally_range(
                        arch,
                        stream,
                        i + 1,
                        close,
                        match_of,
                        mult.saturating_mul(count.max(1) as u64),
                        speed,
                        per_macro,
                        writers,
                        total_bytes,
                        max_speed,
                    );
                    i = close;
                }
            }
            Inst::Halt => return,
            _ => {}
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{CodegenStyle, SchedulePlan, Strategy};
    use crate::sim::simulate;

    fn archs() -> Vec<ArchConfig> {
        vec![ArchConfig::paper_default(), ArchConfig::fig4_default()]
    }

    fn one_stream(arch: &ArchConfig, insts: Vec<Inst>) -> Program {
        let mut p = Program::new(arch.n_cores);
        p.add_stream(0, insts);
        p
    }

    #[test]
    fn all_shipped_lowerings_certify_clean() {
        for arch in archs() {
            let plan = SchedulePlan {
                tasks: 24,
                active_macros: 8,
                n_in: arch.n_in,
                write_speed: arch.write_speed,
            };
            for strategy in Strategy::ALL_EXTENDED {
                for style in [CodegenStyle::Unrolled, CodegenStyle::Looped] {
                    let program = strategy.codegen_styled(&arch, &plan, style).unwrap();
                    let report =
                        verify_program(&arch, &program, &VerifyOptions::for_strategy(strategy));
                    assert!(
                        report.ok(),
                        "{strategy:?}/{style:?}: {:?}",
                        report.first_error()
                    );
                    assert!(
                        report.warnings.is_empty(),
                        "{strategy:?}/{style:?}: {:?}",
                        report.warnings
                    );
                    assert!(report.lower_bound_cycles > 0);
                }
            }
        }
    }

    #[test]
    fn lower_bound_never_exceeds_simulation() {
        for arch in archs() {
            let plan = SchedulePlan {
                tasks: 24,
                active_macros: 8,
                n_in: arch.n_in,
                write_speed: arch.write_speed,
            };
            for strategy in Strategy::ALL_EXTENDED {
                for style in [CodegenStyle::Unrolled, CodegenStyle::Looped] {
                    let program = strategy.codegen_styled(&arch, &plan, style).unwrap();
                    let mut report =
                        verify_program(&arch, &program, &VerifyOptions::for_strategy(strategy));
                    let cycles = simulate(&arch, &program, strategy.sim_options())
                        .unwrap()
                        .stats
                        .cycles;
                    assert!(
                        report.certify_cycles(cycles),
                        "{strategy:?}/{style:?}: bound {} > sim {cycles}",
                        report.lower_bound_cycles
                    );
                }
            }
        }
    }

    #[test]
    fn compute_during_write_is_caught() {
        let arch = ArchConfig::paper_default();
        let p = one_stream(
            &arch,
            vec![
                Inst::Wrw { m: 0, tile: 1 },
                Inst::Vmm {
                    m: 0,
                    n_vec: 1,
                    tile: 1,
                },
                Inst::Halt,
            ],
        );
        let r = verify_program(&arch, &p, &VerifyOptions::default());
        assert!(matches!(
            r.first_error(),
            Some(VerifyError::ComputeDuringWrite { site, m: 0 }) if site.at == 1
        ));
        let text = r.first_error().unwrap().to_string();
        assert!(text.contains("@1") && text.contains("vmm"), "{text}");
    }

    #[test]
    fn wrong_tile_is_caught_with_site() {
        let arch = ArchConfig::paper_default();
        let p = one_stream(
            &arch,
            vec![
                Inst::Wrw { m: 0, tile: 7 },
                Inst::WaitW { m: 0 },
                Inst::Vmm {
                    m: 0,
                    n_vec: 1,
                    tile: 9,
                },
                Inst::Halt,
            ],
        );
        let r = verify_program(&arch, &p, &VerifyOptions::default());
        assert!(matches!(
            r.first_error(),
            Some(VerifyError::WrongTile { want: 9, have: 7, .. })
        ));
    }

    #[test]
    fn double_issue_is_caught() {
        let arch = ArchConfig::paper_default();
        let p = one_stream(
            &arch,
            vec![
                Inst::Wrw { m: 0, tile: 1 },
                Inst::Wrw { m: 0, tile: 2 },
                Inst::Halt,
            ],
        );
        let r = verify_program(&arch, &p, &VerifyOptions::default());
        assert!(r
            .errors
            .iter()
            .any(|e| matches!(e, VerifyError::DoubleWrite { m: 0, .. })));
    }

    #[test]
    fn dead_wait_is_a_warning_not_an_error() {
        let arch = ArchConfig::paper_default();
        let p = one_stream(&arch, vec![Inst::WaitW { m: 3 }, Inst::Halt]);
        let r = verify_program(&arch, &p, &VerifyOptions::default());
        assert!(r.ok());
        assert!(matches!(
            r.warnings.first(),
            Some(VerifyWarning::DeadWait { m: 3, .. })
        ));
    }

    #[test]
    fn buffer_overflow_and_underflow_are_caught() {
        let arch = ArchConfig::paper_default();
        let over = one_stream(
            &arch,
            vec![
                Inst::LdIn { n_vec: u16::MAX },
                Inst::Halt,
            ],
        );
        let r = verify_program(&arch, &over, &VerifyOptions::default());
        assert!(matches!(
            r.first_error(),
            Some(VerifyError::BufferOverflow { .. })
        ));

        let under = one_stream(&arch, vec![Inst::StOut { n_vec: 1 }, Inst::Halt]);
        let r = verify_program(&arch, &under, &VerifyOptions::default());
        assert!(matches!(
            r.first_error(),
            Some(VerifyError::BufferUnderflow { .. })
        ));
    }

    #[test]
    fn loop_occupancy_drift_is_flagged() {
        let arch = ArchConfig::paper_default();
        let p = one_stream(
            &arch,
            vec![
                Inst::Loop { count: 4 },
                Inst::LdIn { n_vec: 1 },
                Inst::EndLoop,
                Inst::Halt,
            ],
        );
        let r = verify_program(&arch, &p, &VerifyOptions::default());
        assert!(r
            .warnings
            .iter()
            .any(|w| matches!(w, VerifyWarning::LoopOccupancyDrift { delta: 32, .. })));
    }

    #[test]
    fn structural_errors_are_located() {
        let arch = ArchConfig::paper_default();
        let p = one_stream(
            &arch,
            vec![Inst::Loop { count: 2 }, Inst::Delay { cycles: 1 }, Inst::Halt],
        );
        let r = verify_program(&arch, &p, &VerifyOptions::default());
        assert!(r
            .errors
            .iter()
            .any(|e| matches!(e, VerifyError::UnbalancedLoop { site } if site.at == 0)));

        let p = one_stream(&arch, vec![Inst::Delay { cycles: 1 }]);
        let r = verify_program(&arch, &p, &VerifyOptions::default());
        assert!(r
            .errors
            .iter()
            .any(|e| matches!(e, VerifyError::MissingHalt { stream: 0, .. })));
    }

    #[test]
    fn barrier_mismatch_is_caught_loop_weighted() {
        let arch = ArchConfig::paper_default();
        let mut p = Program::new(arch.n_cores);
        // Stream 0: 4 dynamic barriers (2 rolled); stream 1: 3 barriers.
        p.add_stream(
            0,
            vec![
                Inst::Loop { count: 2 },
                Inst::Barrier,
                Inst::Barrier,
                Inst::EndLoop,
                Inst::Halt,
            ],
        );
        p.add_stream(
            1,
            vec![Inst::Barrier, Inst::Barrier, Inst::Barrier, Inst::Halt],
        );
        let r = verify_program(&arch, &p, &VerifyOptions::default());
        assert!(r.errors.iter().any(|e| matches!(
            e,
            VerifyError::BarrierMismatch { stream: 1, count: 3, expect: 4, .. }
        )));
    }

    #[test]
    fn shared_macro_is_caught() {
        let arch = ArchConfig::paper_default();
        let mut p = Program::new(arch.n_cores);
        p.add_stream(0, vec![Inst::Wrw { m: 0, tile: 1 }, Inst::WaitW { m: 0 }, Inst::Halt]);
        p.add_stream(0, vec![Inst::WaitW { m: 0 }, Inst::Halt]);
        let r = verify_program(&arch, &p, &VerifyOptions::default());
        assert!(r
            .errors
            .iter()
            .any(|e| matches!(e, VerifyError::SharedMacro { core: 0, m: 0, a: 0, b: 1 })));
    }

    #[test]
    fn intra_overlap_legality_depends_on_options() {
        let arch = ArchConfig::paper_default();
        // wrw while computing: illegal without intra overlap, legal with.
        let insts = vec![
            Inst::Wrw { m: 0, tile: 1 },
            Inst::WaitW { m: 0 },
            Inst::LdIn { n_vec: 1 },
            Inst::Vmm {
                m: 0,
                n_vec: 1,
                tile: 1,
            },
            Inst::Wrw { m: 0, tile: 2 },
            Inst::WaitC { m: 0 },
            Inst::WaitW { m: 0 },
            Inst::StOut { n_vec: 1 },
            Inst::Halt,
        ];
        let p = one_stream(&arch, insts);
        let strict = verify_program(&arch, &p, &VerifyOptions::default());
        assert!(strict
            .errors
            .iter()
            .any(|e| matches!(e, VerifyError::WriteDuringCompute { .. })));
        let relaxed = verify_program(
            &arch,
            &p,
            &VerifyOptions {
                allow_intra_overlap: true,
            },
        );
        assert!(relaxed.ok(), "{:?}", relaxed.first_error());
    }

    #[test]
    fn bound_violation_certification() {
        let arch = ArchConfig::paper_default();
        let plan = SchedulePlan::full_chip(&arch, 32);
        let program = Strategy::GeneralizedPingPong.codegen(&arch, &plan).unwrap();
        let mut report = verify_program(&arch, &program, &VerifyOptions::default());
        assert!(report.lower_bound_cycles > 0);
        assert!(!report.certify_cycles(report.lower_bound_cycles - 1));
        assert!(matches!(
            report.errors.last(),
            Some(VerifyError::BoundViolation { .. })
        ));
    }

    #[test]
    fn zero_loop_is_an_error_with_offset() {
        let arch = ArchConfig::paper_default();
        let mut p = Program::new(arch.n_cores);
        p.streams.push(crate::isa::Stream {
            core: 0,
            insts: vec![Inst::Loop { count: 0 }, Inst::EndLoop, Inst::Halt],
        });
        let r = verify_program(&arch, &p, &VerifyOptions::default());
        assert!(r
            .errors
            .iter()
            .any(|e| matches!(e, VerifyError::ZeroLoop { site } if site.at == 0)));
    }
}

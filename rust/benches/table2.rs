//! Bench/repro: paper Table II — "the discrepancy between theory and
//! practice": fractional-macro model vs integer-macro simulation for
//! generalized ping-pong at band ∈ {256, 128, 64, 32, 16, 8} B/cycle.
//! Runs through the parallel sweep runner.  `cargo bench --bench table2`

use gpp_pim::report::benchkit::{section, Bench};
use gpp_pim::report::figures;
use gpp_pim::sweep::SweepRunner;

/// The paper's Table II, verbatim, for side-by-side comparison.
const PAPER: [(u64, f64, u32, &str, &str, f64, f64); 6] = [
    (256, 82.05, 80, "1.56:1", "1.5:1", 78.08, 75.00),
    (128, 54.01, 49, "2.37:1", "2.5:1", 59.31, 54.69),
    (64, 36.26, 36, "3.53:1", "3.5:1", 44.14, 43.75),
    (32, 24.71, 24, "5.18:1", "5:1", 32.37, 31.25),
    (16, 17.02, 16, "7.52:1", "7:1", 23.49, 21.88),
    (8, 11.83, 11, "10.82:1", "10:1", 16.91, 15.63),
];

fn main() -> anyhow::Result<()> {
    const VECTORS: u32 = 16384;
    let runner = SweepRunner::default();
    section("Table II — theory vs practice (this reproduction)");
    let rows = figures::table2_with(&runner, VECTORS)?;
    println!("{}", figures::table2_table(&rows).to_ascii());

    section("Table II — paper values for comparison");
    println!(
        "{:>6} {:>14} {:>16} {:>12} {:>14} {:>12} {:>14}",
        "band", "macros_theory", "macros_practice", "ratio_thry", "ratio_prac", "perf_thry", "perf_prac"
    );
    for (band, mt, mp, rt, rp, pt, pp) in PAPER {
        println!(
            "{band:>6} {mt:>14.2} {mp:>16} {rt:>12} {rp:>14} {pt:>11.2}% {pp:>13.2}%"
        );
    }

    println!("\nchecks (theory column is closed-form, must match paper < 0.2 macro):");
    for (row, paper) in rows.iter().zip(PAPER) {
        let d_macros = (row.theory_macros - paper.1).abs();
        let d_perf = (100.0 * row.theory_perf - paper.5).abs();
        println!(
            "  band {:>3}: |Δmacros| = {:.3}, |Δperf| = {:.3} pp {}",
            row.bandwidth,
            d_macros,
            d_perf,
            if d_macros < 0.2 && d_perf < 0.5 { "✓" } else { "✗" }
        );
    }

    let m = Bench::new(0, 3).run("table2/regenerate", || {
        figures::table2_with(&runner, VECTORS).unwrap()
    });
    println!("\n{}", m.line());
    println!("{}", runner.summary());
    Ok(())
}

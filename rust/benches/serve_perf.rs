//! Perf bench: batched request serving (`serve/`) — the ISSUE-2
//! acceptance criteria:
//!
//! 1. the serve report (per-request CSV + summary CSV) is byte-identical
//!    across `--jobs` and `--chips` settings at the same seed (asserted
//!    before any timing is reported, so CI's bench-smoke job fails on a
//!    determinism regression);
//! 2. serving throughput stays within 10% of raw sweep throughput on the
//!    equivalent class grid at ≥ 64 requests (EXPERIMENTS.md §Serve) —
//!    the batching layer must not tax the executor it rides on;
//! 3. class batching amortizes simulation: served macro-cycles exceed
//!    simulated macro-cycles by the dedup factor.
//!
//! Writes `BENCH_serve.json` (schema: EXPERIMENTS.md §Tracking) and
//! validates it against the schema before exiting.  Reduced-size runs:
//! set `GPP_SERVE_REQUESTS` / `GPP_BENCH_ITERS` (CI bench-smoke).
//! `cargo bench --bench serve_perf`

use gpp_pim::arch::ArchConfig;
use gpp_pim::report::benchkit::{
    env_u64, section, validate_bench_json, write_bench_json, Bench, BenchRecord,
};
use gpp_pim::serve::{synthetic_traffic, ServeEngine, TrafficConfig};
use gpp_pim::sweep::{default_jobs, SweepGrid, SweepPoint, SweepRunner};
use std::path::Path;

/// Full report text: the byte-comparison surface.
fn report_csv(engine: &ServeEngine, requests: &[gpp_pim::serve::Request]) -> String {
    let report = engine.run(requests).expect("serve");
    format!(
        "{}{}",
        report.to_table().to_csv(),
        report.summary_table().to_csv()
    )
}

fn main() -> anyhow::Result<()> {
    let arch = ArchConfig::paper_default;
    let jobs = default_jobs();
    let n_requests = env_u64("GPP_SERVE_REQUESTS", 512) as u32;
    let iters = env_u64("GPP_BENCH_ITERS", 5) as usize;
    let traffic_cfg = TrafficConfig {
        requests: n_requests,
        seed: 7,
        mean_gap_cycles: 2048,
        ..Default::default()
    };
    let requests = synthetic_traffic(&arch(), &traffic_cfg);
    let mut records = Vec::new();

    section("byte-identical reports: jobs 1 vs N, chips 1 vs 2");
    let base = report_csv(&ServeEngine::new(arch(), 1, 1), &requests);
    for (j, c) in [(jobs, 1usize), (1, 2), (jobs, 2)] {
        let got = report_csv(&ServeEngine::new(arch(), j, c), &requests);
        assert_eq!(
            base, got,
            "serve report diverged at jobs={j} chips={c} (vs jobs=1 chips=1)"
        );
    }
    println!(
        "reports identical across (jobs, chips) ∈ {{(1,1),({jobs},1),(1,2),({jobs},2)}} ({} bytes) ✓",
        base.len()
    );

    // Deterministic simulated-work denominator, measured once.
    let probe = ServeEngine::new(arch(), jobs, 1).run(&requests)?;
    let classes = probe.classes;
    let simulated_macro_cycles: f64 = {
        // Actually-executed work: one simulation per class.
        let mut per_class_served = vec![0u64; classes];
        let mut per_class_macro = vec![0u64; classes];
        for r in &probe.records {
            per_class_served[r.class] += 1;
            per_class_macro[r.class] = r.macro_cycles;
        }
        assert!(per_class_served.iter().all(|&n| n > 0));
        per_class_macro.iter().map(|&m| m as f64).sum()
    };
    println!(
        "\n{} requests -> {} classes; served/simulated macro-cycle amplification {:.2}x",
        probe.requests(),
        classes,
        probe.served_macro_cycles() as f64 / simulated_macro_cycles.max(1.0)
    );

    section("wall-clock: serve, sequential vs parallel vs 2 chips");
    let bench = Bench::new(1, iters);
    let m_seq = bench.run("serve/sequential", || {
        ServeEngine::new(arch(), 1, 1).run(&requests).unwrap().requests()
    });
    println!("{}", m_seq.line());
    records.push(BenchRecord::new(&m_seq, Some(simulated_macro_cycles)));
    let m_par = bench.run(&format!("serve/parallel-{jobs}"), || {
        ServeEngine::new(arch(), jobs, 1).run(&requests).unwrap().requests()
    });
    println!("{}", m_par.line());
    records.push(BenchRecord::new(&m_par, Some(simulated_macro_cycles)));
    let m_chips = bench.run(&format!("serve/chips-2-parallel-{jobs}"), || {
        ServeEngine::new(arch(), jobs, 2).run(&requests).unwrap().requests()
    });
    println!("{}", m_chips.line());
    records.push(BenchRecord::new(&m_chips, Some(simulated_macro_cycles)));
    println!(
        "-> {:.2}x serve speedup with {jobs} workers",
        m_seq.median_secs() / m_par.median_secs()
    );

    section("serving overhead vs raw sweep on the equivalent class grid");
    // The same unique simulations, submitted as a bare sweep grid: the
    // serving layer's batching/merging/report overhead is the difference.
    let set = {
        use gpp_pim::serve::Batcher;
        Batcher::new(arch()).batch(&requests).expect("batch")
    };
    let grid = SweepGrid::from_points(
        set.batches
            .iter()
            .map(|b| {
                SweepPoint::new(b.class.arch.clone(), b.class.strategy, b.class.plan)
            })
            .collect(),
    );
    let m_sweep = bench.run(&format!("serve/raw-sweep-equiv-{jobs}"), || {
        SweepRunner::new(jobs).run_all(&grid).unwrap().len()
    });
    println!("{}", m_sweep.line());
    records.push(BenchRecord::new(&m_sweep, Some(simulated_macro_cycles)));
    let overhead = m_par.median_secs() / m_sweep.median_secs() - 1.0;
    println!(
        "-> serving overhead over raw sweep: {:.1}% (target <= 10% at >= 64 requests)",
        100.0 * overhead
    );
    // Hard gate at 2.5x the target so CI timing noise can't flake the
    // job; the 10% figure is the tracked target (EXPERIMENTS.md §Serve).
    if n_requests >= 64 {
        if overhead > 0.25 {
            anyhow::bail!(
                "serving throughput fell far below raw sweep throughput \
                 ({:.1}% overhead at {} requests; target <= 10%, hard limit 25%)",
                100.0 * overhead,
                n_requests
            );
        } else if overhead > 0.10 {
            println!(
                "WARNING: overhead {:.1}% exceeds the 10% target (within the 25% noise margin)",
                100.0 * overhead
            );
        }
    }

    let out = Path::new("BENCH_serve.json");
    write_bench_json(out, &records)?;
    let text = std::fs::read_to_string(out)?;
    let n = validate_bench_json(&text).map_err(|e| anyhow::anyhow!("schema: {e}"))?;
    println!("\n[wrote {} ({n} records, schema OK)]", out.display());
    Ok(())
}

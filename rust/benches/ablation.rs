//! Ablation bench — the design choices DESIGN.md calls out:
//!
//!  A1. GPP *stagger offsets*: remove the prologue delays and let the
//!      FIFO bus arbiter self-organize.  How much of GPP's win is the
//!      explicit stagger vs just dropping barriers?
//!  A2. GPP *stream granularity*: per-macro streams vs one-stream-per-core
//!      (approximated by naive ping-pong's barrier structure).
//!  A3. Instruction issue cost 0 vs 1 vs 4 cycles: how sensitive are the
//!      paper's numbers to control-unit overhead the model ignores?
//!  A4. Intra-macro vs inter-macro ping-pong at equal resources.
//!
//! `cargo bench --bench ablation`

use gpp_pim::arch::ArchConfig;
use gpp_pim::isa::{Inst, Program};
use gpp_pim::report::benchkit::section;
use gpp_pim::sched::{SchedulePlan, Strategy};
use gpp_pim::sim::{simulate, SimOptions};

/// GPP codegen with the stagger delays stripped (ablation A1).
fn gpp_without_stagger(arch: &ArchConfig, plan: &SchedulePlan) -> Program {
    let full = Strategy::GeneralizedPingPong.codegen(arch, plan).unwrap();
    Program {
        n_cores: full.n_cores,
        streams: full
            .streams
            .into_iter()
            .map(|mut s| {
                s.insts.retain(|i| !matches!(i, Inst::Delay { .. }));
                s
            })
            .collect(),
    }
}

fn cycles(arch: &ArchConfig, program: &Program, opts: SimOptions) -> u64 {
    simulate(arch, program, opts).unwrap().stats.cycles
}

fn main() {
    // Compute-heavy working point at exactly-Eq.4 bandwidth: the regime
    // where scheduling quality matters most.
    let mut arch = ArchConfig::paper_default();
    arch.core_buffer_bytes = 1 << 22;
    arch.bandwidth = 32;
    let plan = SchedulePlan {
        tasks: 1024,
        active_macros: 16, // Eq. 4 for tp=384, tr=128, band=32, s=8
        n_in: 12,
        write_speed: 8,
    };

    section("A1 — stagger offsets vs FIFO self-organization");
    let staggered = Strategy::GeneralizedPingPong.codegen(&arch, &plan).unwrap();
    let unstaggered = gpp_without_stagger(&arch, &plan);
    let c_st = cycles(&arch, &staggered, SimOptions::default());
    let c_un = cycles(&arch, &unstaggered, SimOptions::default());
    // Peak-demand comparison needs an uncapped bus (the SoC sees the raw
    // burst; a capped bus hides it behind arbitration).
    let mut wide = arch.clone();
    wide.bandwidth = 4096;
    let peak_st = simulate(&wide, &staggered, SimOptions::default())
        .unwrap()
        .stats
        .peak_bus_rate;
    let peak_un = simulate(&wide, &unstaggered, SimOptions::default())
        .unwrap()
        .stats
        .peak_bus_rate;
    println!("gpp with stagger    : {c_st} cycles, raw peak demand {peak_st} B/cyc");
    println!("gpp without stagger : {c_un} cycles, raw peak demand {peak_un} B/cyc");
    println!(
        "-> on a capped bus FIFO self-organizes to within {:.1}% of the\n\
         \x20  staggered schedule, but the stagger cuts the raw burst a\n\
         \x20  shared SoC sees from {} to {} B/cyc (the Fig. 3c argument)\n",
        100.0 * (c_st as f64 - c_un as f64).abs() / c_un as f64,
        peak_un,
        peak_st
    );

    section("A2 — barrier-free per-macro streams vs banked barriers");
    let naive = Strategy::NaivePingPong.codegen(&arch, &plan).unwrap();
    let c_naive = cycles(&arch, &naive, SimOptions::default());
    println!("gpp (per-macro streams)      : {c_st} cycles");
    println!("naive (per-core, barriers)   : {c_naive} cycles");
    println!(
        "-> removing the bank barrier + balancing bus demand: {:.2}x\n",
        c_naive as f64 / c_st as f64
    );

    section("A3 — sensitivity to instruction issue cost");
    for cost in [0u32, 1, 4] {
        let opts = SimOptions {
            issue_cost: cost,
            ..SimOptions::default()
        };
        let c = cycles(&arch, &staggered, opts);
        println!(
            "issue_cost = {cost}: {c} cycles ({:+.2}% vs ideal)",
            100.0 * (c as f64 - c_st as f64) / c_st as f64
        );
    }
    println!("-> the model's zero-control-overhead assumption is safe here\n");

    section("A4 — intra-macro vs inter-macro ping-pong (equal resources)");
    let intra = Strategy::IntraMacroPingPong.codegen(&arch, &plan).unwrap();
    let c_intra = cycles(
        &arch,
        &intra,
        SimOptions {
            allow_intra_overlap: true,
            ..SimOptions::default()
        },
    );
    println!("inter-macro naive ping-pong : {c_naive} cycles");
    println!("intra-macro ping-pong       : {c_intra} cycles");
    println!("generalized ping-pong       : {c_st} cycles");
    println!(
        "-> intra removes the bank barrier ({:.2}x vs inter) but still \
         bursts the bus; gpp adds the stagger ({:.2}x vs intra)",
        c_naive as f64 / c_intra as f64,
        c_intra as f64 / c_st as f64
    );
}

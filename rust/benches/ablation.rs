//! Ablation bench — the design choices DESIGN.md calls out:
//!
//!  A1. GPP *stagger offsets*: remove the prologue delays and let the
//!      FIFO bus arbiter self-organize.  How much of GPP's win is the
//!      explicit stagger vs just dropping barriers?
//!  A2. GPP *stream granularity*: per-macro streams vs one-stream-per-core
//!      (approximated by naive ping-pong's barrier structure).
//!  A3. Instruction issue cost 0 vs 1 vs 4 cycles: how sensitive are the
//!      paper's numbers to control-unit overhead the model ignores?
//!  A4. Intra-macro vs inter-macro ping-pong at equal resources.
//!
//! All standard-codegen points run as one batch on the parallel sweep
//! runner; the hand-modified (unstaggered) program goes through
//! `simulate_in` on a recycled workspace.  `cargo bench --bench ablation`

use gpp_pim::arch::ArchConfig;
use gpp_pim::isa::{Inst, Program};
use gpp_pim::report::benchkit::section;
use gpp_pim::sched::{SchedulePlan, Strategy};
use gpp_pim::sim::{simulate_in, SimOptions, SimWorkspace};
use gpp_pim::sweep::{SweepGrid, SweepPoint, SweepRunner};

/// GPP codegen with the stagger delays stripped (ablation A1).
fn gpp_without_stagger(arch: &ArchConfig, plan: &SchedulePlan) -> Program {
    let full = Strategy::GeneralizedPingPong.codegen(arch, plan).unwrap();
    Program {
        n_cores: full.n_cores,
        streams: full
            .streams
            .into_iter()
            .map(|mut s| {
                s.insts.retain(|i| !matches!(i, Inst::Delay { .. }));
                s
            })
            .collect(),
    }
}

fn main() {
    // Compute-heavy working point at exactly-Eq.4 bandwidth: the regime
    // where scheduling quality matters most.
    let mut arch = ArchConfig::paper_default();
    arch.core_buffer_bytes = 1 << 22;
    arch.bandwidth = 32;
    let plan = SchedulePlan {
        tasks: 1024,
        active_macros: 16, // Eq. 4 for tp=384, tr=128, band=32, s=8
        n_in: 12,
        write_speed: 8,
    };
    // Peak-demand comparison needs an uncapped bus (the SoC sees the raw
    // burst; a capped bus hides it behind arbitration).
    let mut wide = arch.clone();
    wide.bandwidth = 4096;

    // One batch: [gpp, naive, intra, gpp@wide, gpp@issue-cost 0/1/4].
    let runner = SweepRunner::default();
    let mut grid = SweepGrid::new();
    grid.push(SweepPoint::new(arch.clone(), Strategy::GeneralizedPingPong, plan));
    grid.push(SweepPoint::new(arch.clone(), Strategy::NaivePingPong, plan));
    grid.push(SweepPoint::new(arch.clone(), Strategy::IntraMacroPingPong, plan));
    grid.push(SweepPoint::new(wide.clone(), Strategy::GeneralizedPingPong, plan));
    let costs = [0u32, 1, 4];
    for cost in costs {
        grid.push(SweepPoint::with_opts(
            arch.clone(),
            Strategy::GeneralizedPingPong,
            plan,
            SimOptions {
                issue_cost: cost,
                ..SimOptions::default()
            },
        ));
    }
    let stats = runner.run_all(&grid).expect("ablation grid");
    let (c_st, c_naive, c_intra) = (stats[0].cycles, stats[1].cycles, stats[2].cycles);
    let peak_st = stats[3].peak_bus_rate;

    // The hand-stripped program is not a (strategy, plan) point — run it
    // through the recycled-workspace engine path directly.
    let unstaggered = gpp_without_stagger(&arch, &plan);
    let mut ws = SimWorkspace::new();
    let c_un = simulate_in(&arch, &unstaggered, SimOptions::default(), &mut ws)
        .unwrap()
        .stats
        .cycles;
    let peak_un = simulate_in(&wide, &unstaggered, SimOptions::default(), &mut ws)
        .unwrap()
        .stats
        .peak_bus_rate;

    section("A1 — stagger offsets vs FIFO self-organization");
    println!("gpp with stagger    : {c_st} cycles, raw peak demand {peak_st} B/cyc");
    println!("gpp without stagger : {c_un} cycles, raw peak demand {peak_un} B/cyc");
    println!(
        "-> on a capped bus FIFO self-organizes to within {:.1}% of the\n\
         \x20  staggered schedule, but the stagger cuts the raw burst a\n\
         \x20  shared SoC sees from {} to {} B/cyc (the Fig. 3c argument)\n",
        100.0 * (c_st as f64 - c_un as f64).abs() / c_un as f64,
        peak_un,
        peak_st
    );

    section("A2 — barrier-free per-macro streams vs banked barriers");
    println!("gpp (per-macro streams)      : {c_st} cycles");
    println!("naive (per-core, barriers)   : {c_naive} cycles");
    println!(
        "-> removing the bank barrier + balancing bus demand: {:.2}x\n",
        c_naive as f64 / c_st as f64
    );

    section("A3 — sensitivity to instruction issue cost");
    for (cost, st) in costs.iter().zip(&stats[4..7]) {
        println!(
            "issue_cost = {cost}: {} cycles ({:+.2}% vs ideal)",
            st.cycles,
            100.0 * (st.cycles as f64 - c_st as f64) / c_st as f64
        );
    }
    println!("-> the model's zero-control-overhead assumption is safe here\n");

    section("A4 — intra-macro vs inter-macro ping-pong (equal resources)");
    println!("inter-macro naive ping-pong : {c_naive} cycles");
    println!("intra-macro ping-pong       : {c_intra} cycles");
    println!("generalized ping-pong       : {c_st} cycles");
    println!(
        "-> intra removes the bank barrier ({:.2}x vs inter) but still \
         bursts the bus; gpp adds the stagger ({:.2}x vs intra)",
        c_naive as f64 / c_intra as f64,
        c_intra as f64 / c_st as f64
    );
    println!("\n{}", runner.summary());
}

//! Bench/repro: paper Fig. 7(a)–(d) — runtime bandwidth adaptation from
//! the `time_PIM == time_rewrite` design point (128 macros, s = 8,
//! band = 512 B/cycle): normalized performance, result-memory /
//! bandwidth / macro utilization for the three strategies as the SoC
//! cuts the accelerator's bandwidth by n = 1 … 64.  Runs through the
//! parallel sweep runner.  `cargo bench --bench fig7`

use gpp_pim::report::benchkit::{section, Bench};
use gpp_pim::report::figures;
use gpp_pim::sweep::SweepRunner;

fn main() -> anyhow::Result<()> {
    const VECTORS: u32 = 16384;
    const DIVISORS: [u32; 7] = [1, 2, 4, 8, 16, 32, 64];
    let runner = SweepRunner::default();

    section("Fig. 7(a) — normalized performance under bandwidth reduction");
    let rows = figures::fig7_with(&runner, &DIVISORS, VECTORS)?;
    println!("{}", figures::fig7a_table(&rows).to_ascii());

    section("Fig. 7(b)-(d) — result-memory / bandwidth / macro utilization");
    println!("{}", figures::fig7bcd_table(&rows).to_ascii());

    let last = rows.last().unwrap();
    println!(
        "at band/64: gpp/insitu = {:.2}x, gpp/naive = {:.2}x   [paper: 5.38x / 7.71x]",
        last.sim_gpp / last.sim_insitu,
        last.sim_gpp / last.sim_naive
    );
    println!("shape: gpp keeps BOTH bus and macro utilization high; in-situ");
    println!("wastes the bus (c), naive wastes macros (d) — as in the paper.");

    let m = Bench::new(0, 3).run("fig7/regenerate", || {
        figures::fig7_with(&runner, &DIVISORS, VECTORS).unwrap()
    });
    println!("\n{}", m.line());
    println!("{}", runner.summary());
    Ok(())
}

//! Bench/repro: paper Fig. 4 — naive ping-pong macro utilization and
//! `time_PIM/time_rewrite` ratio vs `n_in` (32×32-B macro, 4×8-B OU,
//! s = 4 B/cycle).  Prints the series the paper plots plus the harness
//! wall-time.  `cargo bench --bench fig4`

use gpp_pim::report::benchkit::{section, Bench};
use gpp_pim::report::figures;
use gpp_pim::sweep::SweepRunner;

fn main() -> anyhow::Result<()> {
    let runner = SweepRunner::default();
    section("Fig. 4 — naive ping-pong utilization vs n_in");
    let rows = figures::fig4_with(&runner)?;
    println!("{}", figures::fig4_table(&rows).to_ascii());

    let at8 = rows.iter().find(|r| r.n_in == 8).unwrap();
    println!(
        "sweet spot: n_in = 8 -> tP/tR = {:.2}, util(model) = {:.3}, util(sim) = {:.3}",
        at8.ratio_tp_tr, at8.util_model, at8.util_sim
    );
    println!("paper: utilization peaks at exactly n_in = 8 where tP == tR ✓");

    let m = Bench::new(1, 5).run("fig4/regenerate", || {
        figures::fig4_with(&runner).unwrap()
    });
    println!("\n{}", m.line());
    Ok(())
}

//! Perf bench: the parallel sweep runner on the full reproduction
//! workload (`repro all`: Fig. 4 + Fig. 6 + Fig. 7 + Table II +
//! headline).  Demonstrates the ISSUE-1 acceptance criteria:
//!
//! 1. parallel output is byte-identical to sequential output (the
//!    concatenated CSV of every figure/table is compared), and
//! 2. wall-clock speedup on a multi-core host (target >= 3x; the exact
//!    figure depends on the core count of the machine running this).
//!
//! Also measures the raw runner on a uniform grid so a macro-cycles/s
//! rate can be reported, and writes everything to `BENCH_sweep.json`
//! (schema: EXPERIMENTS.md §Tracking, self-validated before exit).
//! Reduced-size runs: set `GPP_SWEEP_VECTORS` / `GPP_BENCH_ITERS` (CI
//! bench-smoke).  `cargo bench --bench sweep_perf`

use gpp_pim::arch::ArchConfig;
use gpp_pim::report::benchkit::{
    env_u64, section, validate_bench_json, write_bench_json, Bench, BenchRecord,
};
use gpp_pim::report::figures;
use gpp_pim::sched::{SchedulePlan, Strategy};
use gpp_pim::sweep::{default_jobs, SweepGrid, SweepRunner};
use std::path::Path;

/// Default work size for the repro sweep: large enough that per-point
/// simulation dominates, small enough to iterate the bench a few times.
const DEFAULT_VECTORS: u64 = 8192;

/// The full repro-all CSV through a fresh runner with `jobs` workers.
/// (Fresh per call so the codegen cache warms inside the measured
/// region, exactly as a CLI `repro all --jobs N` invocation would.)
fn repro_all(jobs: usize, vectors: u32) -> String {
    let runner = SweepRunner::new(jobs);
    figures::repro_all_csv(&runner, vectors).expect("repro all")
}

fn main() -> anyhow::Result<()> {
    let jobs = default_jobs();
    let vectors = env_u64("GPP_SWEEP_VECTORS", DEFAULT_VECTORS) as u32;
    let iters = env_u64("GPP_BENCH_ITERS", 5) as usize;
    let mut records = Vec::new();

    section("byte-identical output: sequential vs parallel repro all");
    let seq_csv = repro_all(1, vectors);
    let par_csv = repro_all(jobs, vectors);
    assert_eq!(
        seq_csv, par_csv,
        "parallel repro output must be byte-identical to sequential"
    );
    println!(
        "sequential and {jobs}-worker CSV outputs identical ({} bytes) ✓",
        seq_csv.len()
    );

    section("wall-clock: repro all, sequential vs parallel");
    let bench = Bench::new(1, iters);
    let m_seq = bench.run("repro_all/sequential", || repro_all(1, vectors));
    println!("{}", m_seq.line());
    let m_par = bench.run(&format!("repro_all/parallel-{jobs}"), || {
        repro_all(jobs, vectors)
    });
    println!("{}", m_par.line());
    let speedup = m_seq.median_secs() / m_par.median_secs();
    println!(
        "-> {speedup:.2}x speedup with {jobs} workers (target >= 3x on a multi-core host)"
    );
    records.push(BenchRecord::new(&m_seq, None));
    records.push(BenchRecord::new(&m_par, None));

    section("raw runner rate on a uniform grid (macro-cycles/s)");
    // A uniform grid lets us attribute simulated work exactly: each point
    // contributes cycles x active macros.
    let mut arch = ArchConfig::paper_default();
    arch.core_buffer_bytes = 1 << 22;
    let plans: Vec<SchedulePlan> = (0..24)
        .map(|i| SchedulePlan {
            tasks: 1024 + 128 * i,
            active_macros: 128,
            n_in: 4,
            write_speed: 8,
        })
        .collect();
    let grid = SweepGrid::cartesian(&[arch], &plans, &Strategy::ALL);
    // Simulated work is deterministic; take it from one evaluation.
    let probe = SweepRunner::sequential().run_all(&grid)?;
    let macro_cycles: f64 = probe
        .iter()
        .map(|s| s.cycles as f64 * s.active_macros() as f64)
        .sum();
    for (label, j) in [("grid/sequential", 1usize), ("grid/parallel", jobs)] {
        let m = bench.run(label, || {
            SweepRunner::new(j).run_all(&grid).unwrap().len()
        });
        println!(
            "{}   -> {:.1}M macro-cycles/s",
            m.line(),
            macro_cycles / m.median_secs() / 1e6
        );
        records.push(BenchRecord::new(&m, Some(macro_cycles)));
    }

    let out = Path::new("BENCH_sweep.json");
    write_bench_json(out, &records)?;
    let text = std::fs::read_to_string(out)?;
    let n = validate_bench_json(&text).map_err(|e| anyhow::anyhow!("schema: {e}"))?;
    println!("\n[wrote {} ({n} records, schema OK)]", out.display());
    Ok(())
}

//! Perf bench: the fleet dispatch timeline (`fleet/timeline.rs`) — the
//! ISSUE-6 resilience layer's throughput surface:
//!
//! 1. correctness gate before any timing: with an empty fault plan,
//!    `dispatch_fifo_faulty` reproduces `dispatch_fifo` bit-for-bit for
//!    every placement policy (a determinism regression fails the bench,
//!    and therefore CI's bench-smoke job, before a number is printed);
//! 2. `fleet/timeline-faults-off` — the fault-free fast path on a
//!    synthetic dispatch stream (no simulation: service cycles come from
//!    a closed-form per-(chip, class) function, so this times the
//!    queueing machinery alone);
//! 3. `fleet/timeline-faults-on` — the same stream under a seeded MTBF
//!    fault schedule plus scripted fail/join events, exercising
//!    redispatch, migration charging, and availability windows;
//! 4. `fleet/timeline-throttled` — the same stream under a
//!    bandwidth-throttle storm (ISSUE 9), exercising epoch tracking and
//!    per-placement service repricing.  Gated first: a throttle plan
//!    with *identity* repricing ([`FaultCharges::FREE`]) must leave the
//!    timeline bit-identical to the no-fault run — throttle epochs are
//!    pure pricing, never scheduling.
//!
//! The tracked rate is timeline events/sec (dispatches per iteration
//! over median wall time, carried in the `macro_cycles_per_s` field of
//! the shared BENCH_*.json schema).  Writes `BENCH_fleet.json`
//! (EXPERIMENTS.md §Tracking) and validates it before exiting.
//! Reduced-size runs: set `GPP_FLEET_DISPATCHES` / `GPP_BENCH_ITERS`
//! (CI bench-smoke).  `cargo bench --bench fleet_perf`

use gpp_pim::fleet::{
    dispatch_fifo, dispatch_fifo_faulty, Dispatch, FaultCharges, FaultPlan, OverloadConfig,
    PlacementPolicy,
};
use gpp_pim::report::benchkit::{
    env_u64, section, validate_bench_json, write_bench_json, Bench, BenchRecord,
};
use std::path::Path;

const CHIPS: usize = 8;
const CLASSES: usize = 16;

/// Synthetic dispatch stream: deterministic arrivals dense enough that
/// queues actually form (mean service ~1.3k cycles vs 37-cycle gaps).
fn stream(n: usize) -> Vec<Dispatch> {
    (0..n)
        .map(|i| Dispatch {
            id: i as u32,
            arrival_cycle: i as u64 * 37,
            class: i % CLASSES,
        })
        .collect()
}

/// Closed-form service cost `service_on(dispatch_index, chip)`:
/// class-dominated with a per-chip skew, so LeastLoaded/SED decisions
/// are non-trivial.
fn service_on(i: usize, chip: usize) -> u64 {
    1_000 + (i % CLASSES) as u64 * 211 + chip as u64 * 17
}

fn main() -> anyhow::Result<()> {
    let n = env_u64("GPP_FLEET_DISPATCHES", 100_000) as usize;
    let iters = env_u64("GPP_BENCH_ITERS", 5) as usize;
    let dispatches = stream(n);
    // MTBF-driven failures/rejoins across the run plus scripted events
    // early enough to redispatch real backlog.
    let plan = FaultPlan::parse("mtbf@400000@9,fail@50000@1,join@90000@1,drain@120000@5")
        .expect("fault plan");
    // Bandwidth-throttle storm (ISSUE 9): long epochs on two chips
    // across the ~3.7M-cycle stream, one of them restored mid-run.
    let storm = FaultPlan::parse(
        "throttle@20000@0@25,restore@1500000@0,throttle@60000@3@50,throttle@900000@3@10",
    )
    .expect("throttle plan");
    // Flat migration/cold pricing: the bench times the timeline, not the
    // write model (the engine integration charges real weight bytes).
    // The throttled closure scales service inversely with the effective
    // bandwidth percentage — the closed-form shape of a write-bound
    // refit, cheap enough that the bench still times the machinery.
    let migrate = |_from: usize, _to: usize, _pct: u8| (1u64 << 20, 2_048u64);
    let cold = |_chip: usize, _pct: u8| (8u64 << 20, 16_384u64);
    let throttled = |base: u64, _i: usize, _chip: usize, pct: u8| base * 100 / pct.max(1) as u64;
    let charges = FaultCharges {
        migrate: &migrate,
        cold: &cold,
        throttled: &throttled,
    };
    let mut records = Vec::new();

    section("correctness gate: empty plan == fault-free path, all policies");
    for policy in PlacementPolicy::ALL {
        let plain = dispatch_fifo(CHIPS, &dispatches, service_on, policy.instance().as_mut());
        let faulty = dispatch_fifo_faulty(
            CHIPS,
            &dispatches,
            service_on,
            policy.instance().as_mut(),
            &FaultPlan::none(),
            None,
            OverloadConfig::default(),
            &FaultCharges::FREE,
        );
        assert_eq!(
            plain,
            faulty,
            "faulty path with empty plan diverged from dispatch_fifo ({})",
            policy.name()
        );
        // Throttle epochs are pure pricing: with identity repricing the
        // storm must not move a single placement or counter.
        let inert = dispatch_fifo_faulty(
            CHIPS,
            &dispatches,
            service_on,
            policy.instance().as_mut(),
            &storm,
            None,
            OverloadConfig::default(),
            &FaultCharges::FREE,
        );
        assert_eq!(
            plain,
            inert,
            "throttle storm with identity repricing moved the timeline ({})",
            policy.name()
        );
    }
    println!(
        "empty-plan and identity-throttle paths bit-identical to dispatch_fifo over {} policies ✓",
        PlacementPolicy::ALL.len()
    );

    section(&format!("wall-clock: {n} dispatches on {CHIPS} chips (least-loaded)"));
    let bench = Bench::new(1, iters);
    let events_per_iter = n as f64;
    let m_off = bench.run("fleet/timeline-faults-off", || {
        dispatch_fifo(
            CHIPS,
            &dispatches,
            service_on,
            PlacementPolicy::LeastLoaded.instance().as_mut(),
        )
        .makespan
    });
    println!("{}", m_off.line());
    records.push(BenchRecord::new(&m_off, Some(events_per_iter)));

    let m_on = bench.run("fleet/timeline-faults-on", || {
        dispatch_fifo_faulty(
            CHIPS,
            &dispatches,
            service_on,
            PlacementPolicy::LeastLoaded.instance().as_mut(),
            &plan,
            None,
            OverloadConfig::default(),
            &charges,
        )
        .makespan
    });
    println!("{}", m_on.line());
    records.push(BenchRecord::new(&m_on, Some(events_per_iter)));
    println!(
        "-> fault machinery overhead: {:.1}% ({:.2}M events/s off, {:.2}M events/s on)",
        100.0 * (m_on.median_secs() / m_off.median_secs() - 1.0),
        events_per_iter / m_off.median_secs() / 1e6,
        events_per_iter / m_on.median_secs() / 1e6,
    );

    let m_thr = bench.run("fleet/timeline-throttled", || {
        dispatch_fifo_faulty(
            CHIPS,
            &dispatches,
            service_on,
            PlacementPolicy::LeastLoaded.instance().as_mut(),
            &storm,
            None,
            OverloadConfig::default(),
            &charges,
        )
        .makespan
    });
    println!("{}", m_thr.line());
    records.push(BenchRecord::new(&m_thr, Some(events_per_iter)));

    // Sanity on the faulty run itself: the plan must actually have
    // bitten (failures redispatch work and charge migration bytes).
    let t = dispatch_fifo_faulty(
        CHIPS,
        &dispatches,
        service_on,
        PlacementPolicy::LeastLoaded.instance().as_mut(),
        &plan,
        None,
        OverloadConfig::default(),
        &charges,
    );
    assert!(t.faults.redispatched > 0, "fault plan never redispatched");
    assert!(t.faults.migration_bytes > 0, "no migration charged");
    let served = t.placements.iter().filter(|p| !p.dropped).count();
    println!(
        "faulted run: {served}/{} served, {} redispatched, {} dropped, {} migration bytes",
        n, t.faults.redispatched, t.faults.dropped, t.faults.migration_bytes
    );

    // And the throttled run: epochs must have repriced real work (the
    // scaled closure stretches every placement inside an epoch).
    let t = dispatch_fifo_faulty(
        CHIPS,
        &dispatches,
        service_on,
        PlacementPolicy::LeastLoaded.instance().as_mut(),
        &storm,
        None,
        OverloadConfig::default(),
        &charges,
    );
    let plain = dispatch_fifo(
        CHIPS,
        &dispatches,
        service_on,
        PlacementPolicy::LeastLoaded.instance().as_mut(),
    );
    assert!(
        t.makespan > plain.makespan,
        "throttle storm never stretched the timeline ({} vs {})",
        t.makespan,
        plain.makespan
    );

    let out = Path::new("BENCH_fleet.json");
    write_bench_json(out, &records)?;
    let text = std::fs::read_to_string(out)?;
    let k = validate_bench_json(&text).map_err(|e| anyhow::anyhow!("schema: {e}"))?;
    println!("\n[wrote {} ({k} records, schema OK)]", out.display());
    Ok(())
}

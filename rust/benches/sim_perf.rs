//! Perf bench: the cycle-accurate simulator itself (the L3 hot path).
//! Reports simulated macro-cycles per wall-second — the §Perf target in
//! EXPERIMENTS.md is >= 50M macro-cycles/s on the full-chip workload —
//! for both the fresh-allocation path (`simulate`) and the recycled
//! workspace path (`simulate_in`), so the zero-realloc win is visible.
//! `cargo bench --bench sim_perf`

use gpp_pim::arch::ArchConfig;
use gpp_pim::report::benchkit::{section, Bench};
use gpp_pim::sched::{SchedulePlan, Strategy};
use gpp_pim::sim::{simulate, simulate_in, SimOptions, SimWorkspace};

fn main() {
    section("simulator throughput (event-accelerated engine)");
    let bench = Bench::new(1, 7);

    for (name, tasks, active, n_in, band) in [
        ("full-chip/256-macros/8k-tasks", 8192u32, 256u32, 4u32, 512u64),
        ("full-chip/256-macros/32k-tasks", 32768, 256, 4, 512),
        ("contended-bus/64-macros", 8192, 64, 4, 32),
        ("compute-heavy/128-macros", 8192, 128, 16, 128),
    ] {
        let mut arch = ArchConfig::paper_default();
        arch.bandwidth = band;
        arch.core_buffer_bytes = 1 << 22;
        let plan = SchedulePlan {
            tasks,
            active_macros: active,
            n_in,
            write_speed: 8,
        };
        for strategy in Strategy::ALL {
            let program = strategy.codegen(&arch, &plan).unwrap();
            let mut sim_cycles = 0u64;
            let m = bench.run(&format!("{name}/{}", strategy.name()), || {
                let r = simulate(&arch, &program, SimOptions::default()).unwrap();
                sim_cycles = r.stats.cycles;
                r.stats.cycles
            });
            let macro_cycles = sim_cycles as f64 * active as f64;
            println!(
                "{}   -> {:.1}M macro-cycles/s ({} sim cycles)",
                m.line(),
                macro_cycles / m.median_secs() / 1e6,
                sim_cycles
            );
        }
    }

    section("engine reuse: fresh Engine::new vs recycled SimWorkspace");
    // Short runs magnify per-run setup cost — the regime a sweep over
    // many small design points lives in.
    let mut arch = ArchConfig::paper_default();
    arch.core_buffer_bytes = 1 << 22;
    let plan = SchedulePlan {
        tasks: 256,
        active_macros: 256,
        n_in: 4,
        write_speed: 8,
    };
    let program = Strategy::GeneralizedPingPong.codegen(&arch, &plan).unwrap();
    let bench = Bench::new(2, 15);
    let fresh = bench.run("short-run/fresh-alloc", || {
        simulate(&arch, &program, SimOptions::default()).unwrap().stats.cycles
    });
    println!("{}", fresh.line());
    let mut ws = SimWorkspace::new();
    let reused = bench.run("short-run/reused-workspace", || {
        simulate_in(&arch, &program, SimOptions::default(), &mut ws)
            .unwrap()
            .stats
            .cycles
    });
    println!("{}", reused.line());
    println!(
        "-> workspace reuse: {:.2}x on short runs",
        fresh.median_secs() / reused.median_secs()
    );
}

//! Perf bench: the cycle-accurate simulator itself (the L3 hot path).
//! Reports simulated macro-cycles per wall-second — the §Perf target in
//! EXPERIMENTS.md is >= 50M macro-cycles/s on the full-chip workload —
//! for both the fresh-allocation path (`simulate`) and the recycled
//! workspace path (`simulate_in`), so the zero-realloc win is visible.
//!
//! Writes `BENCH_sim.json` (schema: EXPERIMENTS.md §Tracking): the
//! single-point `simulate_in` throughput on the full-chip workload plus
//! the loop-workload fast-forward pair (`sim/loop-gpp/fast-forward` vs
//! `sim/loop-gpp/no-fast-forward`, asserted bit-identical and >= 5x
//! apart), validated against the schema before exiting.
//! Reduced-size runs: set `GPP_SIM_TASKS` / `GPP_FF_TASKS` /
//! `GPP_BENCH_ITERS` (CI bench-smoke).  `cargo bench --bench sim_perf`

use gpp_pim::arch::ArchConfig;
use gpp_pim::report::benchkit::{
    env_u64, section, validate_bench_json, write_bench_json, Bench, BenchRecord,
};
use gpp_pim::sched::{CodegenStyle, SchedulePlan, Strategy};
use gpp_pim::sim::{simulate, simulate_in, SimOptions, SimWorkspace};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let iters = env_u64("GPP_BENCH_ITERS", 7) as usize;
    let full_chip_tasks = env_u64("GPP_SIM_TASKS", 8192) as u32;
    let ff_tasks = env_u64("GPP_FF_TASKS", 65536) as u32;

    section("simulator throughput (event-accelerated engine)");
    let bench = Bench::new(1, iters);

    for (name, tasks, active, n_in, band) in [
        ("full-chip/256-macros/8k-tasks", full_chip_tasks, 256u32, 4u32, 512u64),
        ("full-chip/256-macros/32k-tasks", 4 * full_chip_tasks, 256, 4, 512),
        ("contended-bus/64-macros", full_chip_tasks, 64, 4, 32),
        ("compute-heavy/128-macros", full_chip_tasks, 128, 16, 128),
    ] {
        let mut arch = ArchConfig::paper_default();
        arch.bandwidth = band;
        arch.core_buffer_bytes = 1 << 22;
        let plan = SchedulePlan {
            tasks,
            active_macros: active,
            n_in,
            write_speed: 8,
        };
        for strategy in Strategy::ALL {
            let program = strategy.codegen(&arch, &plan).unwrap();
            let mut sim_cycles = 0u64;
            let m = bench.run(&format!("{name}/{}", strategy.name()), || {
                let r = simulate(&arch, &program, SimOptions::default()).unwrap();
                sim_cycles = r.stats.cycles;
                r.stats.cycles
            });
            let macro_cycles = sim_cycles as f64 * active as f64;
            println!(
                "{}   -> {:.1}M macro-cycles/s ({} sim cycles)",
                m.line(),
                macro_cycles / m.median_secs() / 1e6,
                sim_cycles
            );
        }
    }

    section("engine reuse: fresh Engine::new vs recycled SimWorkspace");
    // Short runs magnify per-run setup cost — the regime a sweep over
    // many small design points lives in.
    let mut arch = ArchConfig::paper_default();
    arch.core_buffer_bytes = 1 << 22;
    let plan = SchedulePlan {
        tasks: 256,
        active_macros: 256,
        n_in: 4,
        write_speed: 8,
    };
    let program = Strategy::GeneralizedPingPong.codegen(&arch, &plan).unwrap();
    let bench = Bench::new(2, (2 * iters).max(2));
    let fresh = bench.run("short-run/fresh-alloc", || {
        simulate(&arch, &program, SimOptions::default()).unwrap().stats.cycles
    });
    println!("{}", fresh.line());
    let mut ws = SimWorkspace::new();
    let reused = bench.run("short-run/reused-workspace", || {
        simulate_in(&arch, &program, SimOptions::default(), &mut ws)
            .unwrap()
            .stats
            .cycles
    });
    println!("{}", reused.line());
    println!(
        "-> workspace reuse: {:.2}x on short runs",
        fresh.median_secs() / reused.median_secs()
    );

    section("steady-state fast-forward: looped gpp, 256 macros");
    // The large-loop workload of the §Sim acceptance gate: a looped-
    // codegen full-chip gpp program whose steady state the engine
    // detects and extrapolates.  Bandwidth covers all write ports
    // (uncontended bus) so the steady state recurs at exactly one
    // iteration — the regime fast-forward is specified for.  Correctness
    // first (bit-identical stats, deterministic), then wall-clock.
    let mut ff_arch = arch.clone();
    ff_arch.bandwidth = 4096; // >= 256 macros x 8 B/cyc
    let ff_plan = SchedulePlan {
        tasks: ff_tasks,
        active_macros: 256,
        n_in: 4,
        write_speed: 8,
    };
    let ff_program = Strategy::GeneralizedPingPong
        .codegen_styled(&ff_arch, &ff_plan, CodegenStyle::Looped)
        .unwrap();
    let slow_opts = SimOptions {
        no_fast_forward: true,
        ..SimOptions::default()
    };
    let fast_run = simulate(&ff_arch, &ff_program, SimOptions::default()).unwrap();
    let slow_run = simulate(&ff_arch, &ff_program, slow_opts.clone()).unwrap();
    assert_eq!(
        fast_run.stats, slow_run.stats,
        "fast-forward must be bit-identical to the slow path"
    );
    assert!(
        fast_run.fast_forward.periods > 0,
        "fast-forward must engage on the loop workload: {:?}",
        fast_run.fast_forward
    );
    println!(
        "fast-forward engaged: {} periods / {} cycles over {} skips (of {} total cycles)",
        fast_run.fast_forward.periods,
        fast_run.fast_forward.cycles,
        fast_run.fast_forward.skips,
        fast_run.stats.cycles
    );
    let ff_bench = Bench::new(1, iters);
    let mut ws = SimWorkspace::new();
    let mut ff_cycles = 0u64;
    let m_fast = ff_bench.run("sim/loop-gpp/fast-forward", || {
        let r = simulate_in(&ff_arch, &ff_program, SimOptions::default(), &mut ws).unwrap();
        ff_cycles = r.stats.cycles;
        r.stats.cycles
    });
    let ff_macro_cycles = ff_cycles as f64 * 256.0;
    println!("{}", m_fast.line());
    let m_slow = ff_bench.run("sim/loop-gpp/no-fast-forward", || {
        simulate_in(&ff_arch, &ff_program, slow_opts.clone(), &mut ws)
            .unwrap()
            .stats
            .cycles
    });
    println!("{}", m_slow.line());
    let ff_speedup = m_slow.median_secs() / m_fast.median_secs();
    println!("-> steady-state fast-forward: {ff_speedup:.1}x on the {ff_tasks}-task loop workload");
    // Hard gate (ample margin: the expected ratio is tasks/active over a
    // handful of detection periods, i.e. tens to hundreds of x).
    assert!(
        ff_speedup >= 5.0,
        "fast-forward speedup {ff_speedup:.2}x below the 5x acceptance gate"
    );

    section("tracking record: single-point simulate_in throughput");
    // The engine-level BENCH_sim.json record (§Tracking): the gpp
    // full-chip point through the recycled-workspace path — the exact
    // per-point cost every sweep and serve simulation pays.
    let plan = SchedulePlan {
        tasks: full_chip_tasks,
        active_macros: 256,
        n_in: 4,
        write_speed: 8,
    };
    let program = Strategy::GeneralizedPingPong.codegen(&arch, &plan).unwrap();
    let mut ws = SimWorkspace::new();
    let mut sim_cycles = 0u64;
    let m = Bench::new(1, iters).run("sim/full-chip-gpp/simulate_in", || {
        let r = simulate_in(&arch, &program, SimOptions::default(), &mut ws).unwrap();
        sim_cycles = r.stats.cycles;
        r.stats.cycles
    });
    let macro_cycles = sim_cycles as f64 * 256.0;
    println!(
        "{}   -> {:.1}M macro-cycles/s",
        m.line(),
        macro_cycles / m.median_secs() / 1e6
    );
    let records = [
        BenchRecord::new(&m, Some(macro_cycles)),
        BenchRecord::new(&m_fast, Some(ff_macro_cycles)),
        BenchRecord::new(&m_slow, Some(ff_macro_cycles)),
    ];
    let out = Path::new("BENCH_sim.json");
    write_bench_json(out, &records)?;
    let text = std::fs::read_to_string(out)?;
    let n = validate_bench_json(&text).map_err(|e| anyhow::anyhow!("schema: {e}"))?;
    println!("\n[wrote {} ({n} records, schema OK)]", out.display());
    Ok(())
}

//! Perf bench: the full-cartesian DSE (`dse --full`) — the consumer the
//! steady-state fast-forward was built for.  Every cartesian point runs
//! all three strategies through the parallel sweep runner with looped
//! codegen; the same grid is then re-run with
//! `SimOptions::no_fast_forward` and the two result sets are asserted
//! **bit-identical** before any timing is reported.
//!
//! Writes `BENCH_dse.json` (schema: EXPERIMENTS.md §Tracking):
//! `dse/full-cartesian/fast-forward` and
//! `dse/full-cartesian/no-fast-forward`, validated before exiting.
//! Reduced-size runs: set `GPP_DSE_POINTS` (cartesian point cap),
//! `GPP_DSE_TASKS` (tasks per point) and `GPP_BENCH_ITERS` (CI
//! bench-smoke).  `cargo bench --bench dse_perf`

use gpp_pim::arch::ArchConfig;
use gpp_pim::model::dse::CartesianSpace;
use gpp_pim::report::benchkit::{
    env_u64, section, validate_bench_json, write_bench_json, Bench, BenchRecord,
};
use gpp_pim::sched::{CodegenStyle, SchedulePlan, Strategy};
use gpp_pim::sim::{simulate, SimOptions};
use gpp_pim::sweep::SweepRunner;
use std::path::Path;

/// Deterministically trim the space to at most `cap` cartesian points by
/// popping from the longest axis (fixed priority on ties) until it fits.
fn trim_to_cap(space: &mut CartesianSpace, cap: usize) {
    while space.len() > cap {
        let lens = [
            space.bandwidths.len(),
            space.n_in.len(),
            space.cores.len(),
            space.macros_per_core.len(),
        ];
        let max = *lens.iter().max().unwrap();
        if max <= 1 {
            break; // every trimmable axis is down to one value
        }
        if space.bandwidths.len() == max {
            space.bandwidths.pop();
        } else if space.n_in.len() == max {
            space.n_in.pop();
        } else if space.cores.len() == max {
            space.cores.pop();
        } else {
            space.macros_per_core.pop();
        }
    }
}

fn main() -> anyhow::Result<()> {
    let iters = env_u64("GPP_BENCH_ITERS", 5) as usize;
    let tasks = env_u64("GPP_DSE_TASKS", 16384) as u32;
    let point_cap = env_u64("GPP_DSE_POINTS", 48) as usize;

    let arch = ArchConfig::paper_default();
    let mut space = CartesianSpace {
        cores: vec![4, 8, 16],
        macros_per_core: vec![8, 16],
        n_in: vec![2, 4, 8],
        bandwidths: vec![64, 128, 256, 512],
        // One deep buffer: this bench measures evaluation speed, not the
        // buffer-feasibility frontier (the CLI default axes cover that).
        buffers: vec![1 << 20],
        tasks,
        write_speed: 8,
    };
    trim_to_cap(&mut space, point_cap.max(1));
    space.validate().map_err(|e| anyhow::anyhow!("{e}"))?;

    section("full-cartesian DSE: fast-forward on vs off (byte-identity first)");
    println!(
        "space: {} points x {} strategies, {} tasks/point",
        space.len(),
        Strategy::ALL.len(),
        space.tasks
    );

    // Correctness gate: identical stats for every point, fast-forward on
    // vs off, plus proof the fast-forward actually engaged.
    let runner = SweepRunner::default();
    let grid_on = space
        .grid(&arch, CodegenStyle::Looped, true)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let grid_off = space
        .grid(&arch, CodegenStyle::Looped, false)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let on = runner.run_all(&grid_on).map_err(|e| anyhow::anyhow!("{e}"))?;
    let off = runner.run_all(&grid_off).map_err(|e| anyhow::anyhow!("{e}"))?;
    assert_eq!(
        on, off,
        "fast-forward on/off must produce byte-identical stats on every DSE point"
    );
    let probe_plan = SchedulePlan {
        tasks,
        active_macros: arch.total_macros().min(tasks),
        n_in: 4,
        write_speed: 8,
    };
    let mut probe_arch = arch.clone();
    probe_arch.core_buffer_bytes = 1 << 20;
    // Uncontended bus for the engagement probe: the steady state then
    // recurs at exactly one loop iteration, so detection is guaranteed.
    probe_arch.bandwidth = 4096;
    let probe = Strategy::GeneralizedPingPong
        .codegen_styled(&probe_arch, &probe_plan, CodegenStyle::Looped)
        .unwrap();
    let probe_run = simulate(&probe_arch, &probe, SimOptions::default()).unwrap();
    assert!(
        probe_run.fast_forward.periods > 0,
        "fast-forward must engage on the DSE workload: {:?}",
        probe_run.fast_forward
    );

    // Timing: whole-space evaluation, fresh runner per iteration so the
    // codegen cache cost is measured too (both arms pay it equally).
    let bench = Bench::new(1, iters);
    let m_fast = bench.run("dse/full-cartesian/fast-forward", || {
        SweepRunner::default().run_all(&grid_on).unwrap().len()
    });
    println!("{}", m_fast.line());
    let m_slow = bench.run("dse/full-cartesian/no-fast-forward", || {
        SweepRunner::default().run_all(&grid_off).unwrap().len()
    });
    println!("{}", m_slow.line());
    let speedup = m_slow.median_secs() / m_fast.median_secs();
    println!(
        "-> fast-forward: {:.1}x end-to-end on the full-cartesian DSE \
         ({} points; naive ping-pong has no looped lowering yet and runs \
         the slow path in both arms)",
        speedup,
        space.len()
    );

    let records = [
        BenchRecord::new(&m_fast, None),
        BenchRecord::new(&m_slow, None),
    ];
    let out = Path::new("BENCH_dse.json");
    write_bench_json(out, &records)?;
    let text = std::fs::read_to_string(out)?;
    let n = validate_bench_json(&text).map_err(|e| anyhow::anyhow!("schema: {e}"))?;
    println!("\n[wrote {} ({n} records, schema OK)]", out.display());
    Ok(())
}

//! Perf bench: the full-cartesian DSE (`dse --full`) — the consumer the
//! steady-state fast-forward was built for.  Every cartesian point runs
//! all three strategies through the parallel sweep runner with looped
//! codegen; the same grid is then re-run with
//! `SimOptions::no_fast_forward` and the two result sets are asserted
//! **bit-identical** before any timing is reported.
//!
//! A second section times the bound-and-prune search (`--search
//! pruned`) against exhaustive evaluation on a 180-point space, after
//! asserting (a) the session-level `dse_topk`/`dse_pareto` tables are
//! byte-identical between the two modes and (b) the pruned search
//! simulates at least 3x fewer points than it scores.
//!
//! Writes `BENCH_dse.json` (schema: EXPERIMENTS.md §Tracking):
//! `dse/full-cartesian/fast-forward`,
//! `dse/full-cartesian/no-fast-forward`, `dse/exhaustive-search` and
//! `dse/pruned-search` (whose `macro_cycles_per_s` slot carries the
//! pruned-vs-exhaustive speedup ratio), validated before exiting.
//! Reduced-size runs: set `GPP_DSE_POINTS` (cartesian point cap),
//! `GPP_DSE_TASKS` (tasks per point), `GPP_DSE_SEARCH_TASKS` (tasks per
//! point in the search section; the 180-point space is never trimmed)
//! and `GPP_BENCH_ITERS` (CI bench-smoke).
//! `cargo bench --bench dse_perf`

use gpp_pim::api::{MemorySink, RunSpec, Session, SinkSet};
use gpp_pim::arch::ArchConfig;
use gpp_pim::model::dse::CartesianSpace;
use gpp_pim::report::benchkit::{
    env_u64, section, validate_bench_json, write_bench_json, Bench, BenchRecord,
};
use gpp_pim::sched::{CodegenStyle, SchedulePlan, Strategy};
use gpp_pim::sim::{simulate, SimOptions};
use gpp_pim::sweep::SweepRunner;
use std::path::Path;

/// Deterministically trim the space to at most `cap` cartesian points by
/// popping from the longest axis (fixed priority on ties) until it fits.
fn trim_to_cap(space: &mut CartesianSpace, cap: usize) {
    while space.len() > cap {
        let lens = [
            space.bandwidths.len(),
            space.n_in.len(),
            space.cores.len(),
            space.macros_per_core.len(),
        ];
        let max = *lens.iter().max().unwrap();
        if max <= 1 {
            break; // every trimmable axis is down to one value
        }
        if space.bandwidths.len() == max {
            space.bandwidths.pop();
        } else if space.n_in.len() == max {
            space.n_in.pop();
        } else if space.cores.len() == max {
            space.cores.pop();
        } else {
            space.macros_per_core.pop();
        }
    }
}

fn main() -> anyhow::Result<()> {
    let iters = env_u64("GPP_BENCH_ITERS", 5) as usize;
    let tasks = env_u64("GPP_DSE_TASKS", 16384) as u32;
    let point_cap = env_u64("GPP_DSE_POINTS", 48) as usize;

    let arch = ArchConfig::paper_default();
    let mut space = CartesianSpace {
        cores: vec![4, 8, 16],
        macros_per_core: vec![8, 16],
        n_in: vec![2, 4, 8],
        bandwidths: vec![64, 128, 256, 512],
        // One deep buffer: this bench measures evaluation speed, not the
        // buffer-feasibility frontier (the CLI default axes cover that).
        buffers: vec![1 << 20],
        tasks,
        write_speed: 8,
    };
    trim_to_cap(&mut space, point_cap.max(1));
    space.validate().map_err(|e| anyhow::anyhow!("{e}"))?;

    section("full-cartesian DSE: fast-forward on vs off (byte-identity first)");
    println!(
        "space: {} points x {} strategies, {} tasks/point",
        space.len(),
        Strategy::ALL.len(),
        space.tasks
    );

    // Correctness gate: identical stats for every point, fast-forward on
    // vs off, plus proof the fast-forward actually engaged.
    let runner = SweepRunner::default();
    let grid_on = space
        .grid(&arch, CodegenStyle::Looped, true)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let grid_off = space
        .grid(&arch, CodegenStyle::Looped, false)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let on = runner.run_all(&grid_on).map_err(|e| anyhow::anyhow!("{e}"))?;
    let off = runner.run_all(&grid_off).map_err(|e| anyhow::anyhow!("{e}"))?;
    assert_eq!(
        on, off,
        "fast-forward on/off must produce byte-identical stats on every DSE point"
    );
    let probe_plan = SchedulePlan {
        tasks,
        active_macros: arch.total_macros().min(tasks),
        n_in: 4,
        write_speed: 8,
    };
    let mut probe_arch = arch.clone();
    probe_arch.core_buffer_bytes = 1 << 20;
    // Uncontended bus for the engagement probe: the steady state then
    // recurs at exactly one loop iteration, so detection is guaranteed.
    probe_arch.bandwidth = 4096;
    let probe = Strategy::GeneralizedPingPong
        .codegen_styled(&probe_arch, &probe_plan, CodegenStyle::Looped)
        .unwrap();
    let probe_run = simulate(&probe_arch, &probe, SimOptions::default()).unwrap();
    assert!(
        probe_run.fast_forward.periods > 0,
        "fast-forward must engage on the DSE workload: {:?}",
        probe_run.fast_forward
    );

    // Timing: whole-space evaluation, fresh runner per iteration so the
    // codegen cache cost is measured too (both arms pay it equally).
    let bench = Bench::new(1, iters);
    let m_fast = bench.run("dse/full-cartesian/fast-forward", || {
        SweepRunner::default().run_all(&grid_on).unwrap().len()
    });
    println!("{}", m_fast.line());
    let m_slow = bench.run("dse/full-cartesian/no-fast-forward", || {
        SweepRunner::default().run_all(&grid_off).unwrap().len()
    });
    println!("{}", m_slow.line());
    let speedup = m_slow.median_secs() / m_fast.median_secs();
    println!(
        "-> fast-forward: {:.1}x end-to-end on the full-cartesian DSE \
         ({} points; naive ping-pong has no looped lowering yet and runs \
         the slow path in both arms)",
        speedup,
        space.len()
    );

    // ---- pruned bound-and-prune search vs exhaustive --------------------
    //
    // Its own, wider space: pruning power comes from the number of
    // points the calibrated bound can discard, so the search bench keeps
    // 180 points (the fast-forward arms above are capped much smaller).
    // `GPP_DSE_SEARCH_TASKS` shrinks per-point work, never the space.
    let search_tasks = env_u64("GPP_DSE_SEARCH_TASKS", 4096) as u32;
    let search_top = 3usize;
    let search_space = CartesianSpace {
        cores: vec![2, 4, 8, 16],
        macros_per_core: vec![4, 8, 16],
        n_in: vec![2, 4, 8],
        bandwidths: vec![32, 64, 128, 256, 512],
        buffers: vec![1 << 20],
        tasks: search_tasks,
        write_speed: 8,
    };
    search_space.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
    section("pruned search vs exhaustive (byte-identity gated in-bench)");
    println!(
        "space: {} points x {} strategies, {} tasks/point, top {search_top}",
        search_space.len(),
        Strategy::ALL.len(),
        search_space.tasks
    );

    // Correctness gate 1: the session-level tables — the exact bytes
    // `--csv-dir` would persist — must not move under pruning.
    let spec = format!(
        "dse-full:cores=2,4,8,16:macros=4,8,16:nin=2,4,8:bands=32,64,128,256,512:buffers={}:tasks={search_tasks}:top={search_top}",
        1u64 << 20
    );
    let run_session = |spec: &str| -> anyhow::Result<MemorySink> {
        let mut mem = MemorySink::new();
        Session::new(arch.clone())
            .run(&RunSpec::parse(spec)?, &mut SinkSet::new().with(&mut mem))?;
        Ok(mem)
    };
    let ex_mem = run_session(&spec)?;
    let pr_mem = run_session(&format!("{spec}:search=pruned"))?;
    for name in ["dse_topk", "dse_pareto"] {
        assert_eq!(
            ex_mem.csv(name),
            pr_mem.csv(name),
            "{name} must be byte-identical between exhaustive and pruned search"
        );
    }

    // Correctness gate 2: the pruning actually bites — at least 3x fewer
    // points simulated than scored on this space.
    let audit = search_space
        .sweep_pruned(&arch, &SweepRunner::default(), CodegenStyle::Looped, search_top)
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .audit;
    assert!(!audit.fallback, "calibration fell back to exhaustive on the bench space");
    println!(
        "pruned search: {} of {} points simulated ({:.1}% pruned, {} anchors, epsilon {:.4})",
        audit.points_simulated,
        audit.points_scored,
        audit.pruned_pct(),
        audit.anchors,
        audit.epsilon
    );
    assert!(
        audit.points_simulated * 3 <= audit.points_scored,
        "pruned search must simulate >= 3x fewer points ({} of {})",
        audit.points_simulated,
        audit.points_scored
    );

    // Timing: fresh runner per iteration so both arms pay codegen.
    let m_exhaustive = bench.run("dse/exhaustive-search", || {
        search_space
            .sweep(&arch, &SweepRunner::default(), CodegenStyle::Looped)
            .unwrap()
            .len()
    });
    println!("{}", m_exhaustive.line());
    let m_pruned = bench.run("dse/pruned-search", || {
        search_space
            .sweep_pruned(&arch, &SweepRunner::default(), CodegenStyle::Looped, search_top)
            .unwrap()
            .audit
            .points_simulated
    });
    println!("{}", m_pruned.line());
    let search_speedup = m_exhaustive.median_secs() / m_pruned.median_secs().max(1e-12);
    println!(
        "-> pruned search: {search_speedup:.1}x end-to-end over exhaustive on {} points",
        search_space.len()
    );

    let records = [
        BenchRecord::new(&m_fast, None),
        BenchRecord::new(&m_slow, None),
        BenchRecord::new(&m_exhaustive, None),
        // The speedup rides the metric slot (records carry no free-form
        // fields): exhaustive-median / pruned-median per wall-second
        // convention does not apply here, so store the ratio directly.
        BenchRecord {
            name: m_pruned.name.clone(),
            median_secs: m_pruned.median_secs(),
            macro_cycles_per_s: Some(search_speedup),
        },
    ];
    let out = Path::new("BENCH_dse.json");
    write_bench_json(out, &records)?;
    let text = std::fs::read_to_string(out)?;
    let n = validate_bench_json(&text).map_err(|e| anyhow::anyhow!("schema: {e}"))?;
    println!("\n[wrote {} ({n} records, schema OK)]", out.display());
    Ok(())
}

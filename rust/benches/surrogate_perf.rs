//! Perf bench: surrogate serving (`serve/surrogate`) — the ISSUE-7
//! acceptance criteria:
//!
//! 1. the streaming path ([`ServeEngine::run_traffic`]) is byte-identical
//!    to the materialized path (`run(&synthetic_traffic(..))`) — asserted
//!    before any timing, so CI's bench-smoke job fails on a divergence;
//! 2. `--surrogate eqs` agrees with `--surrogate exact` within 1% on
//!    every per-request service time (the coverage map falls back to
//!    exact calibration outside its validated region);
//! 3. a warm [`ServiceTimeTable`] replays the trace without touching the
//!    simulator again — the cold/warm ratio is the tracked
//!    `surrogate/replay-speedup` record.
//!
//! Writes `BENCH_surrogate.json` (schema: EXPERIMENTS.md §Tracking) and
//! validates it against the schema before exiting.  Reduced-size runs:
//! set `GPP_SURROGATE_REQUESTS` / `GPP_BENCH_ITERS` (CI bench-smoke).
//! `cargo bench --bench surrogate_perf`
//!
//! [`ServeEngine::run_traffic`]: gpp_pim::serve::ServeEngine::run_traffic
//! [`ServiceTimeTable`]: gpp_pim::serve::ServiceTimeTable

use gpp_pim::arch::ArchConfig;
use gpp_pim::report::benchkit::{
    env_u64, section, validate_bench_json, write_bench_json, Bench, BenchRecord,
};
use gpp_pim::serve::{
    synthetic_traffic, ServeEngine, ServiceTimeTable, SurrogateMode, TrafficConfig,
};
use gpp_pim::sweep::default_jobs;
use std::path::Path;
use std::sync::Arc;

/// Full report text: the byte-comparison surface.
fn report_text(report: &gpp_pim::serve::ServeReport) -> String {
    format!(
        "{}{}",
        report.to_table().to_csv(),
        report.summary_table().to_csv()
    )
}

fn main() -> anyhow::Result<()> {
    let arch = ArchConfig::paper_default;
    let jobs = default_jobs();
    let n_requests = env_u64("GPP_SURROGATE_REQUESTS", 200_000) as u32;
    let iters = env_u64("GPP_BENCH_ITERS", 3) as usize;
    // The calibration trace visits the full class catalog; the replay
    // trace is the scale story (default 2·10⁵ requests, env-tunable to
    // 10⁶–10⁷).  Same seed: the replay stream's class set is a superset
    // of the calibration stream's, so a warm table replays sim-free.
    let calib_cfg = TrafficConfig {
        requests: 512,
        seed: 7,
        mean_gap_cycles: 2048,
        ..Default::default()
    };
    let replay_cfg = TrafficConfig {
        requests: n_requests,
        seed: 7,
        mean_gap_cycles: 2048,
        ..Default::default()
    };
    let mut records = Vec::new();

    section("correctness gate: streaming == materialized (bytes)");
    let requests = synthetic_traffic(&arch(), &calib_cfg);
    let direct = report_text(&ServeEngine::new(arch(), jobs, 2).run(&requests)?);
    let streamed = report_text(&ServeEngine::new(arch(), jobs, 2).run_traffic(&calib_cfg)?);
    assert_eq!(
        direct, streamed,
        "run_traffic diverged from run(&synthetic_traffic(..)) at {} requests",
        calib_cfg.requests
    );
    println!(
        "streaming and materialized reports identical ({} bytes) ✓",
        direct.len()
    );

    section("correctness gate: eqs within 1% of exact, per request");
    let exact = ServeEngine::new(arch(), jobs, 1).run_traffic(&calib_cfg)?;
    let eqs = ServeEngine::new(arch(), jobs, 1)
        .with_surrogate(SurrogateMode::Eqs)
        .run_traffic(&calib_cfg)?;
    assert_eq!(exact.records.len(), eqs.records.len());
    let mut worst = 0.0f64;
    for (x, e) in exact.records.iter().zip(&eqs.records) {
        let err = x.service_cycles.abs_diff(e.service_cycles);
        assert!(
            err * 100 <= x.service_cycles,
            "request {}: eqs service {} vs exact {} (> 1%)",
            x.id,
            e.service_cycles,
            x.service_cycles
        );
        worst = worst.max(err as f64 / x.service_cycles.max(1) as f64);
    }
    println!(
        "eqs predicted {} of {} classes; worst per-request error {:.4}% ✓",
        eqs.eqs_classes,
        eqs.classes,
        100.0 * worst
    );

    // Simulated-work denominator for the rate column, measured once on
    // the replay trace.
    let probe = {
        let table = Arc::new(ServiceTimeTable::new());
        let engine = ServeEngine::new(arch(), jobs, 2).with_service_table(Arc::clone(&table));
        engine.run_traffic(&replay_cfg)?
    };
    let served_macro_cycles = probe.served_macro_cycles() as f64;
    println!(
        "\nreplay trace: {} requests -> {} classes, {:.3e} served macro-cycles",
        probe.requests(),
        probe.classes,
        served_macro_cycles
    );

    section("wall-clock: cold calibration vs warm-table replay");
    let bench = Bench::new(1, iters);
    // Cold: a fresh engine per iteration — empty codegen cache, empty
    // service table; every class is calibrated cycle-exactly in-run.
    let m_cold = bench.run(&format!("surrogate/cold-exact-{jobs}"), || {
        ServeEngine::new(arch(), jobs, 2)
            .run_traffic(&replay_cfg)
            .unwrap()
            .requests()
    });
    println!("{}", m_cold.line());
    records.push(BenchRecord::new(&m_cold, Some(served_macro_cycles)));

    // Warm: one shared table, calibrated once above (`probe`); the timed
    // runs are pure event-heap replay — zero simulator invocations.
    let warm_table = Arc::new(ServiceTimeTable::new());
    let warm_engine = ServeEngine::new(arch(), jobs, 2).with_service_table(Arc::clone(&warm_table));
    warm_engine.run_traffic(&replay_cfg)?; // prime the table
    let calibrated = warm_table.len();
    let misses_before = warm_table.misses();
    let m_warm = bench.run(&format!("surrogate/warm-replay-{jobs}"), || {
        warm_engine.run_traffic(&replay_cfg).unwrap().requests()
    });
    println!("{}", m_warm.line());
    assert_eq!(
        warm_table.misses(),
        misses_before,
        "warm replay reached the simulator (table misses grew)"
    );
    records.push(BenchRecord::new(&m_warm, Some(served_macro_cycles)));

    // Eqs, cold: closed-form prediction replaces most calibration sims.
    let m_eqs = bench.run(&format!("surrogate/cold-eqs-{jobs}"), || {
        ServeEngine::new(arch(), jobs, 2)
            .with_surrogate(SurrogateMode::Eqs)
            .run_traffic(&replay_cfg)
            .unwrap()
            .requests()
    });
    println!("{}", m_eqs.line());
    records.push(BenchRecord::new(&m_eqs, Some(served_macro_cycles)));

    let speedup = m_cold.median_secs() / m_warm.median_secs().max(1e-12);
    let req_per_s = probe.requests() as f64 / m_warm.median_secs().max(1e-12);
    println!(
        "-> warm replay {:.2}x faster than cold calibration ({} classes cached; {:.3e} requests/s)",
        speedup, calibrated, req_per_s
    );
    // The tracked speedup record: rate column carries the ratio itself
    // (dimensionless), median_secs the warm replay time it derives from.
    records.push(BenchRecord {
        name: "surrogate/replay-speedup".into(),
        median_secs: m_warm.median_secs(),
        macro_cycles_per_s: Some(speedup),
    });
    records.push(BenchRecord {
        name: format!("surrogate/replay-requests-per-s-{jobs}"),
        median_secs: m_warm.median_secs(),
        macro_cycles_per_s: Some(req_per_s),
    });

    let out = Path::new("BENCH_surrogate.json");
    write_bench_json(out, &records)?;
    let text = std::fs::read_to_string(out)?;
    let n = validate_bench_json(&text).map_err(|e| anyhow::anyhow!("schema: {e}"))?;
    println!("\n[wrote {} ({n} records, schema OK)]", out.display());
    Ok(())
}

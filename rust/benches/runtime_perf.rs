//! Perf bench: the PJRT request path — artifact compile time (one-off)
//! and steady-state execute latency/throughput for the macro-VMM and
//! GeMM artifacts.  Skips gracefully when artifacts are missing.
//! `cargo bench --bench runtime_perf`

use gpp_pim::report::benchkit::{section, Bench};
use gpp_pim::runtime::Runtime;
use gpp_pim::util::rng::XorShift64;
use std::time::Instant;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn main() -> anyhow::Result<()> {
    if !Runtime::available(ARTIFACTS) {
        eprintln!("[skip] artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    section("PJRT runtime — compile (one-off) + execute (request path)");
    let mut rt = Runtime::new(ARTIFACTS)?;
    let mut rng = XorShift64::new(0xBE7C);

    // One-off compile cost (cache miss), per artifact.
    for name in ["macro_vmm_8", "macro_vmm_4", "gemm_16x128x128", "ffn_16x64x128"] {
        let t0 = Instant::now();
        match name {
            "macro_vmm_8" => {
                let x = rng.int8_vec(8 * 32);
                let w = rng.int8_vec(1024);
                rt.execute(name, &[(&x, &[8, 32]), (&w, &[32, 32])])?;
            }
            "macro_vmm_4" => {
                let x = rng.int8_vec(4 * 32);
                let w = rng.int8_vec(1024);
                rt.execute(name, &[(&x, &[4, 32]), (&w, &[32, 32])])?;
            }
            "gemm_16x128x128" => {
                let x = rng.int8_vec(16 * 128);
                let w = rng.int8_vec(128 * 128);
                rt.execute(name, &[(&x, &[16, 128]), (&w, &[128, 128])])?;
            }
            _ => {
                let x = rng.int8_vec(16 * 64);
                let w1 = rng.int8_vec(64 * 128);
                let w2 = rng.int8_vec(128 * 64);
                rt.execute(name, &[(&x, &[16, 64]), (&w1, &[64, 128]), (&w2, &[128, 64])])?;
            }
        }
        println!("compile+first-exec {name:<18} {:>10.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    }

    // Steady-state execute latency (cache hits only).
    let bench = Bench::new(3, 30);
    let x8 = rng.int8_vec(8 * 32);
    let w = rng.int8_vec(1024);
    let m = bench.run("execute/macro_vmm_8", || {
        rt.execute("macro_vmm_8", &[(&x8, &[8, 32]), (&w, &[32, 32])])
            .unwrap()
    });
    println!("{}", m.line());
    println!(
        "  -> {:.0} VMM-batches/s ({:.2} Mvector-MACs/s)",
        1.0 / m.median_secs(),
        8.0 * 1024.0 / m.median_secs() / 1e6
    );

    let xg = rng.int8_vec(16 * 128);
    let wg = rng.int8_vec(128 * 128);
    let m = bench.run("execute/gemm_16x128x128", || {
        rt.execute("gemm_16x128x128", &[(&xg, &[16, 128]), (&wg, &[128, 128])])
            .unwrap()
    });
    println!("{}", m.line());
    println!(
        "  -> {:.2} MMACs/s",
        16.0 * 128.0 * 128.0 / m.median_secs() / 1e6
    );

    // Tile-streamed GeMM through macro_vmm (the coordinator's path).
    let m = bench.run("execute/macro_vmm-tiled-16x128x128", || {
        let mut acc = 0.0f32;
        for _ in 0..16 {
            // 4 k-tiles x 4 n-tiles, batch 8+8
            let out = rt.macro_vmm(&x8, &w, 8).unwrap();
            acc += out[0];
        }
        acc
    });
    println!("{}", m.line());
    Ok(())
}

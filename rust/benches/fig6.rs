//! Bench/repro: paper Fig. 6 — design-phase comparison at band = 128
//! B/cycle: (a) execution time and (b) macro count for the three
//! strategies across `time_rewrite : time_PIM` of 8:1 … 1:8.  Runs
//! through the parallel sweep runner (default: one worker per hardware
//! thread).  `cargo bench --bench fig6`

use gpp_pim::report::benchkit::{section, Bench};
use gpp_pim::report::figures;
use gpp_pim::sweep::SweepRunner;

fn main() -> anyhow::Result<()> {
    const VECTORS: u32 = 32768;
    let runner = SweepRunner::default();
    section("Fig. 6 — design-phase strategy comparison (band = 128 B/cyc)");
    let rows = figures::fig6_with(&runner, VECTORS)?;
    println!("{}", figures::fig6_table(&rows).to_ascii());

    let bal = rows
        .iter()
        .find(|r| (r.ratio_tr_tp - 1.0).abs() < 1e-9)
        .unwrap();
    println!(
        "tr=tp   : gpp == naive ({} vs {} cycles), both ~2x in-situ ({})   [paper: overlap + 2x] ",
        bal.cycles_gpp, bal.cycles_naive, bal.cycles_insitu
    );
    let heavy = rows.last().unwrap();
    println!(
        "tr:tp=1:8 (compute-heavy): gpp {:.2}x vs naive, {:.2}x vs in-situ  [paper @1:7: 2.51x / 5.03x]",
        heavy.gpp_speedup_vs_naive(),
        heavy.gpp_speedup_vs_insitu()
    );
    let wh = &rows[0];
    println!(
        "tr:tp=8:1 (write-heavy)  : gpp macro count {} vs naive {} ({:.2}% fewer) [paper: 43.75%]",
        wh.macros_gpp,
        wh.macros_naive,
        100.0 * (1.0 - wh.macros_gpp as f64 / wh.macros_naive as f64)
    );

    let m = Bench::new(0, 3).run("fig6/regenerate", || {
        figures::fig6_with(&runner, VECTORS).unwrap()
    });
    println!("\n{}", m.line());
    println!("{}", runner.summary());
    Ok(())
}

//! Bench/repro: the paper's abstract/§I headline claims — generalized
//! ping-pong vs naive ping-pong over off-chip bandwidth 8 … 256 B/cycle
//! ("1.22~7.71x") and the full-bandwidth acceleration (">1.67x").
//! `cargo bench --bench headline`

use gpp_pim::report::benchkit::{section, Bench};
use gpp_pim::report::figures;
use gpp_pim::sweep::SweepRunner;

fn main() -> anyhow::Result<()> {
    const VECTORS: u32 = 32768;
    let runner = SweepRunner::default();
    section("Headline — bandwidth sweep 8..256 B/cyc (tp = 4 tr working point)");
    let rows = figures::headline_with(&runner, VECTORS)?;
    println!("{}", figures::headline_table(&rows).to_ascii());

    let factors: Vec<f64> = rows.iter().map(|r| r.gpp_vs_naive()).collect();
    let min = factors.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = factors.iter().cloned().fold(0.0, f64::max);
    println!("gpp vs naive ping-pong across the sweep: {min:.2}x .. {max:.2}x   [paper: 1.22x .. 7.71x]");
    let full = rows.last().unwrap();
    println!(
        "at the widest bandwidth (256 B/cyc): {:.2}x vs naive, {:.2}x vs in-situ   [paper: >1.67x]",
        full.gpp_vs_naive(),
        full.gpp_vs_insitu()
    );

    let m = Bench::new(0, 3).run("headline/regenerate", || {
        figures::headline_with(&runner, VECTORS).unwrap()
    });
    println!("\n{}", m.line());
    Ok(())
}

//! Fleet determinism and placement-policy invariants (ISSUE 3):
//!
//! - every report CSV — reference *and* policy timeline — is
//!   byte-identical across `--jobs`;
//! - `serve.csv`/`serve_summary.csv` (the PR 2 regression surface) are
//!   invariant to fleet composition, chip count and placement policy;
//! - heterogeneous fleets codegen once per *distinct arch × class*, not
//!   per chip;
//! - placement policies genuinely diverge on a skewed traffic mix.

use gpp_pim::arch::ArchConfig;
use gpp_pim::coordinator::{Coordinator, RunConfig};
use gpp_pim::fleet::{FleetConfig, PlacementPolicy};
use gpp_pim::gemm::blas;
use gpp_pim::sched::Strategy;
use gpp_pim::serve::{synthetic_traffic, Batcher, Request, ServeEngine, ServeReport, TrafficConfig};

fn arch() -> ArchConfig {
    ArchConfig::paper_default()
}

/// Two distinct archs (paper + half-bandwidth paper): same geometry, so
/// plans — and with them class structure — align 1:1 across archs.
fn het_fleet() -> FleetConfig {
    let mut slow = arch();
    slow.bandwidth = 256;
    FleetConfig::new(vec![arch(), slow]).unwrap()
}

fn traffic(requests: u32) -> Vec<Request> {
    synthetic_traffic(
        &arch(),
        &TrafficConfig {
            requests,
            seed: 7,
            mean_gap_cycles: 2048,
            ..Default::default()
        },
    )
}

/// Reference CSVs only — the PR 2 byte-comparison surface.
fn reference_csv(engine: &ServeEngine, reqs: &[Request]) -> String {
    let r = engine.run(reqs).unwrap();
    format!("{}{}", r.to_table().to_csv(), r.summary_table().to_csv())
}

/// Everything: reference CSVs + both policy-timeline CSVs.
fn full_csv(engine: &ServeEngine, reqs: &[Request]) -> String {
    let r = engine.run(reqs).unwrap();
    format!(
        "{}{}{}{}",
        r.to_table().to_csv(),
        r.summary_table().to_csv(),
        r.fleet.to_table().to_csv(),
        r.fleet.requests_table().to_csv()
    )
}

#[test]
fn heterogeneous_reports_byte_identical_across_jobs() {
    let reqs = traffic(96);
    for policy in PlacementPolicy::ALL {
        let base = full_csv(&ServeEngine::with_fleet(het_fleet(), policy, 1), &reqs);
        for jobs in [2usize, 4, 16] {
            assert_eq!(
                base,
                full_csv(&ServeEngine::with_fleet(het_fleet(), policy, jobs), &reqs),
                "policy {} diverged at jobs={jobs}",
                policy.name()
            );
        }
    }
}

#[test]
fn reference_csvs_invariant_to_fleet_and_policy() {
    // serve.csv / serve_summary.csv are a pure function of
    // (traffic, reference arch): the PR 2 constructor, homogeneous
    // fleets of any size under any policy, and a heterogeneous fleet
    // sharing the reference arch must all reproduce the same bytes.
    let reqs = traffic(96);
    let base = reference_csv(&ServeEngine::new(arch(), 1, 1), &reqs);
    for policy in PlacementPolicy::ALL {
        for chips in [1usize, 2, 4] {
            assert_eq!(
                base,
                reference_csv(
                    &ServeEngine::with_fleet(
                        FleetConfig::homogeneous(arch(), chips),
                        policy,
                        4
                    ),
                    &reqs
                ),
                "policy {} chips {chips}",
                policy.name()
            );
        }
        assert_eq!(
            base,
            reference_csv(&ServeEngine::with_fleet(het_fleet(), policy, 4), &reqs),
            "heterogeneous fleet, policy {}",
            policy.name()
        );
    }
}

#[test]
fn reference_timeline_matches_direct_coordinator_runs() {
    // In-process PR 2 "fixture": each request's reference service must
    // equal a standalone Coordinator::run of the same workload/config,
    // and queueing must be FIFO in (arrival, id) order — the §Serve
    // latency methodology re-derived independently of the serving
    // engine.  (A committed golden file cannot be blessed in the
    // offline authoring container; this pins the same bytes
    // semantically.)
    let reqs = traffic(48);
    let report = ServeEngine::with_fleet(het_fleet(), PlacementPolicy::LeastLoaded, 4)
        .run(&reqs)
        .unwrap();
    let mut coord = Coordinator::new(arch());
    let expected_service: Vec<u64> = reqs
        .iter()
        .map(|r| coord.run(&r.workload, &r.cfg).unwrap().cycles)
        .collect();
    let mut order: Vec<usize> = (0..reqs.len()).collect();
    order.sort_by_key(|&i| (reqs[i].arrival_cycle, reqs[i].id));
    let mut clock = 0u64;
    let mut expected_queue = vec![0u64; reqs.len()];
    for &i in &order {
        let start = clock.max(reqs[i].arrival_cycle);
        expected_queue[i] = start - reqs[i].arrival_cycle;
        clock = start + expected_service[i];
    }
    assert_eq!(report.records.len(), reqs.len());
    for (i, rec) in report.records.iter().enumerate() {
        assert_eq!(rec.id, reqs[i].id);
        assert_eq!(rec.service_cycles, expected_service[i], "request {i}");
        assert_eq!(rec.queue_cycles, expected_queue[i], "request {i}");
    }
    assert_eq!(report.reference_makespan(), clock);
}

#[test]
fn heterogeneous_codegen_once_per_arch_per_class() {
    let reqs = traffic(64);
    let classes = Batcher::new(arch()).batch(&reqs).unwrap().classes() as u64;
    assert!(classes > 1);
    let engine = ServeEngine::with_fleet(het_fleet(), PlacementPolicy::RoundRobin, 4);
    engine.run(&reqs).unwrap();
    assert_eq!(
        engine.cache().misses(),
        2 * classes,
        "2 distinct archs x {classes} classes must each codegen exactly once"
    );
    assert_eq!(engine.cache().hits(), 0);
    // Re-serving the stream is pure cache hits.
    engine.run(&reqs).unwrap();
    assert_eq!(engine.cache().misses(), 2 * classes);
    assert_eq!(engine.cache().hits(), 2 * classes);
}

#[test]
fn codegen_is_per_distinct_arch_not_per_chip() {
    let reqs = traffic(48);
    let classes = Batcher::new(arch()).batch(&reqs).unwrap().classes() as u64;
    // 6 chips but only 2 distinct archs.
    let mut slow = arch();
    slow.bandwidth = 256;
    let fleet = FleetConfig::new(vec![
        arch(),
        slow.clone(),
        arch(),
        slow.clone(),
        arch(),
        slow,
    ])
    .unwrap();
    let engine = ServeEngine::with_fleet(fleet, PlacementPolicy::LeastLoaded, 4);
    engine.run(&reqs).unwrap();
    assert_eq!(engine.cache().misses(), 2 * classes);
}

/// Skewed mix: one heavy class and one light class, all arriving at
/// cycle 0 in the order H L H L L L — chosen so the three policies
/// provably place differently on a 2-chip fleet whenever
/// `service(H) > service(L)`.
fn skewed_requests() -> Vec<Request> {
    let a = arch();
    // Heavy: 64 tasks squeezed onto 8 macros (8 serial rounds) — an
    // order of magnitude above the light single-task class.
    let heavy = || {
        (
            blas::e2e_ffn(),
            RunConfig {
                active_macros: 8,
                ..RunConfig::from_arch(&a, Strategy::GeneralizedPingPong)
            },
        )
    };
    let light = || {
        (
            blas::square_chain(32, 1, 4),
            RunConfig::from_arch(&a, Strategy::GeneralizedPingPong),
        )
    };
    [heavy(), light(), heavy(), light(), light(), light()]
        .into_iter()
        .enumerate()
        .map(|(i, (workload, cfg))| Request {
            id: i as u32,
            arrival_cycle: 0,
            workload,
            cfg,
        })
        .collect()
}

#[test]
fn policies_diverge_on_a_skewed_mix_but_reference_csvs_do_not() {
    let reqs = skewed_requests();
    let fleet = FleetConfig::homogeneous(arch(), 2);
    let run = |policy| {
        ServeEngine::with_fleet(fleet.clone(), policy, 2)
            .run(&reqs)
            .unwrap()
    };
    let rr = run(PlacementPolicy::RoundRobin);
    let ll = run(PlacementPolicy::LeastLoaded);
    let aff = run(PlacementPolicy::ClassAffinity);

    // The mix really is skewed: the heavy class costs more.
    assert!(
        rr.records[0].service_cycles > rr.records[1].service_cycles,
        "heavy ({}) must out-cost light ({})",
        rr.records[0].service_cycles,
        rr.records[1].service_cycles
    );

    // Acceptance criterion: reference CSVs identical across policies...
    assert_eq!(rr.to_table().to_csv(), ll.to_table().to_csv());
    assert_eq!(rr.to_table().to_csv(), aff.to_table().to_csv());
    assert_eq!(rr.summary_table().to_csv(), ll.summary_table().to_csv());
    assert_eq!(rr.summary_table().to_csv(), aff.summary_table().to_csv());

    // ...while chip assignments — and with them per-request policy
    // latency — differ pairwise.
    let chips = |r: &ServeReport| {
        r.fleet
            .assignments
            .iter()
            .map(|a| a.chip)
            .collect::<Vec<_>>()
    };
    assert_eq!(chips(&rr), vec![0, 1, 0, 1, 0, 1]);
    assert_eq!(chips(&ll), vec![0, 1, 1, 0, 0, 1]);
    assert_eq!(chips(&aff), vec![0, 1, 0, 1, 1, 1]);

    // fleet.csv / fleet_requests.csv (policy-timeline latency) differ.
    assert_ne!(rr.fleet.to_table().to_csv(), ll.fleet.to_table().to_csv());
    assert_ne!(rr.fleet.to_table().to_csv(), aff.fleet.to_table().to_csv());
    assert_ne!(
        rr.fleet.requests_table().to_csv(),
        ll.fleet.requests_table().to_csv()
    );
    assert_ne!(
        ll.fleet.requests_table().to_csv(),
        aff.fleet.requests_table().to_csv()
    );
    assert_ne!(
        rr.fleet.requests_table().to_csv(),
        aff.fleet.requests_table().to_csv()
    );
}

#[test]
fn heterogeneous_service_cycles_follow_the_serving_chip() {
    // Policy-timeline service cycles must come from the *serving* chip's
    // arch, not the reference proxy.  Two identical, deliberately
    // bus-bound in-situ requests (256 macros writing concurrently at
    // 8 B/cyc: 2048 B/cyc of demand) land on chip 0 and chip 1 under
    // round-robin; the half-bandwidth chip must take strictly longer.
    let mut reqs = traffic(64);
    let t = reqs.last().unwrap().arrival_cycle;
    let cfg = RunConfig::from_arch(&arch(), Strategy::InSitu);
    for id in [64u32, 65] {
        reqs.push(Request {
            id,
            arrival_cycle: t,
            workload: blas::square_chain(256, 2, 16),
            cfg,
        });
    }
    let report = ServeEngine::with_fleet(het_fleet(), PlacementPolicy::RoundRobin, 4)
        .run(&reqs)
        .unwrap();
    // Round-robin by dispatch order: even index -> chip 0, odd -> chip 1.
    let a64 = &report.fleet.assignments[64];
    let a65 = &report.fleet.assignments[65];
    assert_eq!((a64.chip, a65.chip), (0, 1));
    let reference = report.records[64].service_cycles;
    assert_eq!(report.records[65].service_cycles, reference, "same class");
    assert_eq!(
        a64.service_cycles, reference,
        "chip 0 is the reference arch"
    );
    assert!(
        a65.service_cycles > reference,
        "half-bandwidth chip served a 2048 B/cyc-demand class in {} cycles, \
         reference took {reference}",
        a65.service_cycles
    );
    // Reference-arch chips always agree with the reference records.
    for (rec, a) in report.records.iter().zip(&report.fleet.assignments) {
        if a.chip == 0 {
            assert_eq!(a.service_cycles, rec.service_cycles, "id {}", rec.id);
        }
    }
}

//! Integration: the PJRT runtime and the full three-layer numerics path.
//!
//! These tests need the AOT artifacts (`make artifacts`).  When the
//! artifacts are missing they no-op with a loud eprintln rather than fail,
//! so `cargo test` stays green on a fresh checkout; CI runs
//! `make artifacts` first and gets the full coverage.

use gpp_pim::arch::ArchConfig;
use gpp_pim::coordinator::{ou_sweep_vmm, Coordinator, RunConfig};
use gpp_pim::gemm::{blas, reference, GemmOp, Workload};
use gpp_pim::runtime::Runtime;
use gpp_pim::sched::Strategy;
use gpp_pim::util::rng::XorShift64;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn runtime() -> Option<Runtime> {
    if !Runtime::available(ARTIFACTS) {
        eprintln!("[skip] artifacts missing — run `make artifacts`");
        return None;
    }
    Some(Runtime::new(ARTIFACTS).expect("runtime"))
}

#[test]
fn macro_vmm_artifact_matches_reference() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = XorShift64::new(0xE2E);
    for n_vec in [1usize, 4, 7, 8, 11, 16] {
        let x = rng.int8_vec(n_vec * 32);
        let w = rng.int8_vec(1024);
        let got = rt.macro_vmm(&x, &w, n_vec).expect("macro_vmm");
        let want = reference::gemm(&x, &w, n_vec, 32, 32);
        assert_eq!(got, want, "n_vec={n_vec}: PJRT != reference");
    }
}

#[test]
fn macro_vmm_artifact_matches_ou_model() {
    // L1 Pallas kernel (via HLO) == the Rust OU-sweep model: the same
    // dataflow expressed twice must agree bit-for-bit.
    let Some(mut rt) = runtime() else { return };
    let arch = ArchConfig::paper_default();
    let mut rng = XorShift64::new(0x0CEA);
    for _ in 0..5 {
        let x = rng.int8_vec(8 * 32);
        let w = rng.int8_vec(1024);
        let pjrt = rt.macro_vmm(&x, &w, 8).unwrap();
        let local = ou_sweep_vmm(&arch, &x, &w, 8);
        assert_eq!(pjrt, local);
    }
}

#[test]
fn gemm_artifact_matches_reference() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = XorShift64::new(0x6E33);
    let x = rng.int8_vec(16 * 128);
    let w = rng.int8_vec(128 * 128);
    let got = rt
        .execute("gemm_16x128x128", &[(&x, &[16, 128]), (&w, &[128, 128])])
        .expect("gemm artifact");
    let want = reference::gemm(&x, &w, 16, 128, 128);
    assert_eq!(got, want, "L2 macro-tiled GeMM != reference");
}

#[test]
fn ffn_artifact_matches_reference() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = XorShift64::new(0xFF9);
    let x = rng.int8_vec(16 * 64);
    let w1 = rng.int8_vec(64 * 128);
    let w2 = rng.int8_vec(128 * 64);
    let got = rt
        .execute(
            "ffn_16x64x128",
            &[(&x, &[16, 64]), (&w1, &[64, 128]), (&w2, &[128, 64])],
        )
        .expect("ffn artifact");
    let want = reference::ffn(&x, &w1, &w2, 16, 64, 128, 64, 7);
    assert_eq!(got, want, "L2 FFN chain != reference");
}

#[test]
fn executable_cache_compiles_once() {
    let Some(mut rt) = runtime() else { return };
    let x = vec![0.0f32; 8 * 32];
    let w = vec![0.0f32; 1024];
    assert_eq!(rt.compiled_count(), 0);
    rt.macro_vmm(&x, &w, 8).unwrap();
    assert_eq!(rt.compiled_count(), 1);
    rt.macro_vmm(&x, &w, 8).unwrap();
    assert_eq!(rt.compiled_count(), 1, "second call must hit the cache");
}

#[test]
fn manifest_shape_mismatch_rejected() {
    let Some(mut rt) = runtime() else { return };
    let x = vec![0.0f32; 4 * 32];
    let w = vec![0.0f32; 1024];
    // macro_vmm_8 expects (8,32): feeding (4,32) must fail fast.
    let err = rt
        .execute("macro_vmm_8", &[(&x, &[4, 32]), (&w, &[32, 32])])
        .unwrap_err();
    assert!(err.to_string().contains("shape"), "{err}");
}

#[test]
fn coordinator_numerics_via_pjrt_exact() {
    if !Runtime::available(ARTIFACTS) {
        eprintln!("[skip] artifacts missing — run `make artifacts`");
        return;
    }
    let mut arch = ArchConfig::paper_default();
    arch.core_buffer_bytes = 1 << 20;
    let mut coord = Coordinator::with_runtime(arch, ARTIFACTS).expect("coordinator");
    let workload = blas::transformer_ffn(8, 64, 128, 1);
    for strategy in Strategy::ALL {
        let cfg = RunConfig {
            check_numerics: true,
            n_in: 8,
            ..RunConfig::from_arch(&coord.arch, strategy)
        };
        let report = coord.run(&workload, &cfg).expect("run");
        let numerics = report.numerics.expect("numerics requested");
        assert!(numerics.via_pjrt, "must use the PJRT path");
        assert_eq!(
            numerics.max_abs_err, 0.0,
            "{strategy:?}: int8-grid GeMM must be exact"
        );
    }
}

#[test]
fn coordinator_numerics_ragged_shapes_via_pjrt() {
    if !Runtime::available(ARTIFACTS) {
        eprintln!("[skip] artifacts missing — run `make artifacts`");
        return;
    }
    let mut arch = ArchConfig::paper_default();
    arch.core_buffer_bytes = 1 << 20;
    let mut coord = Coordinator::with_runtime(arch, ARTIFACTS).expect("coordinator");
    // Deliberately awkward shapes: padding paths on every axis.
    let workload = Workload::new(
        "ragged",
        vec![
            GemmOp { m: 5, k: 45, n: 70 },
            GemmOp { m: 3, k: 100, n: 17 },
        ],
    );
    let cfg = RunConfig {
        check_numerics: true,
        n_in: 4,
        ..RunConfig::from_arch(&coord.arch, Strategy::GeneralizedPingPong)
    };
    let report = coord.run(&workload, &cfg).expect("run");
    assert_eq!(report.numerics.unwrap().max_abs_err, 0.0);
}

#[test]
fn fused_requant_artifact_matches_reference() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = XorShift64::new(0xF0F0);
    let x = rng.int8_vec(8 * 32);
    let w = rng.int8_vec(1024);
    let got = rt
        .execute(
            "macro_vmm_requant_8",
            &[(&x, &[8, 32]), (&w, &[32, 32])],
        )
        .expect("fused artifact");
    // Unfused reference composition: requant(gemm(x, w), shift = 7).
    let acc = reference::gemm(&x, &w, 8, 32, 32);
    let want = reference::requant(&acc, 7);
    assert_eq!(got, want, "fused requant-VMM != reference composition");
}

//! Sweep determinism: a parallel run of any grid must be bit-identical
//! to a sequential run — same `SimStats`, same rendered CSV — because
//! every consumer (figure CSVs, Table II, DSE rankings) assumes results
//! are a pure function of the grid, not of thread scheduling.

use gpp_pim::arch::ArchConfig;
use gpp_pim::model::eqs;
use gpp_pim::report::figures;
use gpp_pim::sched::{SchedulePlan, Strategy};
use gpp_pim::sweep::{SweepGrid, SweepPoint, SweepRunner};

/// Small enough to keep the test quick, large enough that every strategy
/// reaches steady state on some points.
const VECTORS: u32 = 2048;

/// The Fig. 6 grid: 7 `(s, n_in)` ratio points x 3 strategies at
/// band = 128, each strategy at its Eqs. 3-4 macro count.
fn fig6_grid() -> SweepGrid {
    let mut arch = ArchConfig::paper_default();
    arch.bandwidth = 128;
    arch.core_buffer_bytes = 1 << 20;
    let points: [(u32, u32); 7] = [(1, 4), (2, 4), (4, 4), (8, 4), (8, 8), (8, 16), (8, 32)];
    let mut grid = SweepGrid::new();
    for (s, n_in) in points {
        let tr = arch.time_rewrite_at(s);
        let tp = arch.time_pim_at(n_in);
        let (band, sf) = (arch.bandwidth as f64, s as f64);
        let tasks = VECTORS.div_ceil(n_in);
        let mk = |active: f64| SchedulePlan {
            tasks,
            active_macros: (active.round() as u32)
                .min(arch.total_macros())
                .min(tasks)
                .max(1),
            n_in,
            write_speed: s,
        };
        grid.push(SweepPoint::new(
            arch.clone(),
            Strategy::InSitu,
            mk(eqs::num_macros_insitu(band, sf)),
        ));
        grid.push(SweepPoint::new(
            arch.clone(),
            Strategy::NaivePingPong,
            mk(eqs::num_macros_naive(band, sf)),
        ));
        grid.push(SweepPoint::new(
            arch.clone(),
            Strategy::GeneralizedPingPong,
            mk(eqs::num_macros_gpp(tp as f64, tr as f64, band, sf)),
        ));
    }
    grid
}

/// The Fig. 7 adaptation grid: bandwidth divisors 1..64 x 3 strategies
/// from the `tp == tr` design point (band 512, 128 macros, s = 8).
fn fig7_grid() -> SweepGrid {
    let arch = ArchConfig::paper_default();
    let mut grid = SweepGrid::new();
    for n in [1u64, 2, 4, 8, 16, 32, 64] {
        let band = 512 / n;
        for (strategy, active) in [
            (Strategy::InSitu, 64u32),
            (Strategy::NaivePingPong, 128),
            (Strategy::GeneralizedPingPong, 128),
        ] {
            let mut a = arch.clone();
            a.bandwidth = band;
            let tasks = VECTORS / 4;
            grid.push(SweepPoint::new(
                a,
                strategy,
                SchedulePlan {
                    tasks,
                    active_macros: active.min(tasks).max(1),
                    n_in: 4,
                    write_speed: 8,
                },
            ));
        }
    }
    grid
}

#[test]
fn parallel_equals_sequential_on_fig6_and_fig7_grids() {
    for grid in [fig6_grid(), fig7_grid()] {
        let seq = SweepRunner::sequential().run_all(&grid).unwrap();
        for jobs in [2usize, 4, 16] {
            let par = SweepRunner::new(jobs).run_all(&grid).unwrap();
            assert_eq!(seq, par, "jobs={jobs} diverged from sequential");
        }
    }
}

#[test]
fn combined_grid_shares_cache_and_stays_deterministic() {
    // One grid holding both figures' points (as `repro all` does) with
    // duplicated entries: duplicates must hit the codegen cache and the
    // output must stay position-exact.
    let mut grid = fig6_grid();
    let extra: Vec<_> = fig7_grid().points().to_vec();
    for p in extra.clone() {
        grid.push(p);
    }
    for p in extra {
        grid.push(p); // duplicates
    }
    let runner = SweepRunner::new(8);
    let all = runner.run_all(&grid).unwrap();
    assert!(runner.cache().hits() >= 21, "duplicates must hit the cache");
    let n = all.len();
    let dup = fig7_grid().len();
    assert_eq!(&all[n - dup..], &all[n - 2 * dup..n - dup]);
    let seq = SweepRunner::sequential().run_all(&grid).unwrap();
    assert_eq!(all, seq);
}

#[test]
fn figure_rows_are_worker_count_invariant() {
    // End-to-end through the actual figure builders: the rendered CSV
    // text (the artifact users diff) must not depend on the runner.
    let seq = figures::fig6_table(
        &figures::fig6_with(&SweepRunner::sequential(), VECTORS).unwrap(),
    )
    .to_csv();
    let par =
        figures::fig6_table(&figures::fig6_with(&SweepRunner::new(8), VECTORS).unwrap()).to_csv();
    assert_eq!(seq, par);

    let divisors = [1u32, 2, 8, 64];
    let seq = figures::fig7a_table(
        &figures::fig7_with(&SweepRunner::sequential(), &divisors, VECTORS).unwrap(),
    )
    .to_csv();
    let par = figures::fig7a_table(
        &figures::fig7_with(&SweepRunner::new(8), &divisors, VECTORS).unwrap(),
    )
    .to_csv();
    assert_eq!(seq, par);
}

#[test]
fn oversubscribed_runner_is_fine() {
    // More workers than points: the work-stealing loop must not deadlock
    // or drop points.
    let grid = fig7_grid();
    let par = SweepRunner::new(64).run_all(&grid).unwrap();
    assert_eq!(par.len(), grid.len());
}

//! Property tests for the pruned cartesian DSE (`--search pruned`).
//!
//! The contract under test: the bound-and-prune search must reproduce
//! the exhaustive `dse_topk.csv` / `dse_pareto.csv` *bytes* — not just
//! the same winners — on randomized spaces, across worker counts, with
//! partial Phase-A model coverage, and under the forced-bad-anchor
//! exhaustive fallback.

use gpp_pim::api::{MemorySink, RunSpec, Session, SinkSet};
use gpp_pim::arch::ArchConfig;
use gpp_pim::model::dse::CartesianSpace;
use gpp_pim::sched::CodegenStyle;
use gpp_pim::sweep::{pareto_min_by, top_k_by, SweepRunner};

/// Tiny deterministic xorshift64 — the property tests must not depend
/// on ambient randomness.
struct XorShift(u64);

impl XorShift {
    fn new(seed: u64) -> Self {
        Self(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
    /// 1..=max distinct values sampled from `pool`, in sampled order.
    fn pick(&mut self, pool: &[u64], max: usize) -> Vec<u64> {
        let count = 1 + self.below(max.min(pool.len()) as u64) as usize;
        let mut vals: Vec<u64> = Vec::new();
        while vals.len() < count {
            let v = pool[self.below(pool.len() as u64) as usize];
            if !vals.contains(&v) {
                vals.push(v);
            }
        }
        vals
    }
}

fn list(vals: &[u64]) -> String {
    vals.iter().map(u64::to_string).collect::<Vec<_>>().join(",")
}

/// A random but non-degenerate dse-full axis set.  The buffer pool
/// includes a depth small enough to make high-`n_in` points infeasible,
/// so the search also sees `feasible=false` anchors.
fn random_axes(rng: &mut XorShift) -> (String, usize) {
    let cores = rng.pick(&[2, 4, 8], 2);
    let macros = rng.pick(&[2, 4, 8], 2);
    let n_in = rng.pick(&[2, 4, 8], 2);
    let bands = rng.pick(&[32, 64, 128, 256, 512], 3);
    let buffers = rng.pick(&[4 * 1024, 64 * 1024], 2);
    let top = 1 + rng.below(3) as usize;
    let points =
        cores.len() * macros.len() * n_in.len() * bands.len() * buffers.len();
    let spec = format!(
        "dse-full:cores={}:macros={}:nin={}:bands={}:buffers={}:tasks=64:top={top}",
        list(&cores),
        list(&macros),
        list(&n_in),
        list(&bands),
        list(&buffers),
    );
    (spec, points)
}

/// Run one spec string through a fresh session, capturing tables.
fn run(spec: &str) -> MemorySink {
    let session = Session::with_jobs(ArchConfig::paper_default(), 2);
    let mut mem = MemorySink::new();
    session
        .run(&RunSpec::parse(spec).unwrap(), &mut SinkSet::new().with(&mut mem))
        .unwrap();
    mem
}

#[test]
fn pruned_matches_exhaustive_on_random_spaces_and_job_counts() {
    let mut rng = XorShift::new(0x9e3779b97f4a7c15);
    for round in 0..4 {
        let (spec, points) = random_axes(&mut rng);
        let ex = run(&spec);
        let pr1 = run(&format!("{spec}:search=pruned:jobs=1"));
        let pr8 = run(&format!("{spec}:search=pruned:jobs=8"));
        for name in ["dse_topk", "dse_pareto"] {
            let want = ex.csv(name).unwrap();
            assert_eq!(
                Some(&want),
                pr1.csv(name).as_ref(),
                "round {round} ({points} pts): {name} moved under pruning\nspec: {spec}"
            );
            assert_eq!(
                Some(&want),
                pr8.csv(name).as_ref(),
                "round {round} ({points} pts): {name} differs at jobs=8\nspec: {spec}"
            );
        }
        // The audit is jobs-invariant too (pruning decisions are made
        // before any parallel dispatch).
        assert_eq!(pr1.csv("dse_search"), pr8.csv("dse_search"), "round {round}");
        let audit = pr1.csv("dse_search").unwrap();
        let row: Vec<String> =
            audit.lines().nth(1).unwrap().split(',').map(String::from).collect();
        assert_eq!(row[0].parse::<usize>().unwrap(), points, "round {round}");
        assert!(row[1].parse::<usize>().unwrap() <= points, "round {round}");
    }
}

fn small_space() -> CartesianSpace {
    CartesianSpace {
        cores: vec![2, 4],
        macros_per_core: vec![2, 4],
        n_in: vec![2, 4],
        bandwidths: vec![32, 128, 512],
        buffers: vec![64 * 1024],
        tasks: 64,
        write_speed: 8,
    }
}

#[test]
fn coverage_misses_are_never_pruned() {
    // A scorer that disavows half the space (every n_in=4 plan): those
    // points carry no bound, so the search must simulate them all, and
    // every simulated point must agree exactly with the exhaustive run.
    let base = ArchConfig::paper_default();
    let space = small_space();
    let runner = SweepRunner::new(2);
    let exhaustive = space.sweep(&base, &runner, CodegenStyle::Looped).unwrap();
    let pruned = space
        .sweep_pruned_with_scorer(&base, &runner, CodegenStyle::Looped, 2, &|arch, plan| {
            if plan.n_in == 4 {
                return None;
            }
            // The real closed form for the covered half, so calibration
            // passes and pruning stays armed.
            Some(gpp_pim::model::eqs::gpp_cycles_estimate(
                arch.time_pim_at(plan.n_in),
                arch.time_rewrite_at(plan.write_speed),
                plan.tasks as u64,
                plan.active_macros as u64,
                arch.bandwidth,
                plan.write_speed as u64,
            ))
        })
        .unwrap();
    assert!(!pruned.audit.fallback);
    for (i, p) in pruned.points.iter().enumerate() {
        if exhaustive[i].n_in == 4 {
            assert!(p.is_some(), "uncovered point {i} was pruned");
        }
        if let Some(p) = p {
            assert_eq!(*p, exhaustive[i], "simulated point {i} diverged");
        }
    }
    // Every exhaustive top-k / frontier member is among the simulated.
    let feasible: Vec<usize> = (0..exhaustive.len())
        .filter(|&i| exhaustive[i].feasible())
        .collect();
    let k = top_k_by(feasible.len(), 2, |j| {
        exhaustive[feasible[j]].cycles[2].unwrap() as f64
    });
    for &j in &k {
        assert!(pruned.points[feasible[j]].is_some(), "top-k member pruned");
    }
    let front = pareto_min_by(feasible.len(), |j| {
        let p = &exhaustive[feasible[j]];
        vec![
            p.cycles[2].unwrap(),
            p.cores as u64 * p.macros_per_core as u64,
            p.buffer_bytes,
        ]
    });
    for &j in &front {
        assert!(pruned.points[feasible[j]].is_some(), "frontier member pruned");
    }
}

#[test]
fn bad_anchors_force_the_exhaustive_fallback() {
    // A scorer that is confidently wrong everywhere: anchor calibration
    // must detect it (relative error beyond the anchor limit) and
    // disable pruning globally rather than trust the bounds.
    let base = ArchConfig::paper_default();
    let space = small_space();
    let runner = SweepRunner::new(2);
    let exhaustive = space.sweep(&base, &runner, CodegenStyle::Looped).unwrap();
    let pruned = space
        .sweep_pruned_with_scorer(&base, &runner, CodegenStyle::Looped, 2, &|_, _| Some(1))
        .unwrap();
    assert!(pruned.audit.fallback, "a wrong scorer must trip the fallback");
    assert_eq!(pruned.audit.epsilon, 0.0);
    assert_eq!(pruned.audit.points_simulated, space.len());
    for (i, p) in pruned.points.iter().enumerate() {
        assert_eq!(p.as_ref(), Some(&exhaustive[i]), "fallback point {i} diverged");
    }
}

//! Fault-injection determinism and degraded-mode acceptance (ISSUE 6):
//!
//! - the same seed + fault plan yields byte-identical
//!   `fleet.csv`/`fleet_requests.csv` across `--jobs` settings;
//! - a `FaultPlan::none()` engine reproduces the default (fault-free)
//!   path bit-for-bit across every policy, and a *real* plan leaves the
//!   reference CSVs (`serve.csv`/`serve_summary.csv`) untouched;
//! - a scripted chip-failure run completes with every request either
//!   served or explicitly dropped and counted, reports availability
//!   < 1.0 and nonzero migration bytes, and prices redispatch through
//!   the write-cost model;
//! - the SLO autoscaler grows the fleet deterministically.

use gpp_pim::arch::ArchConfig;
use gpp_pim::fleet::{AutoscaleConfig, FaultPlan, FleetConfig, PlacementPolicy};
use gpp_pim::serve::{synthetic_traffic, Request, ServeEngine, TrafficConfig};

fn arch() -> ArchConfig {
    ArchConfig::paper_default()
}

/// Two distinct archs (paper + half-bandwidth paper) — the
/// `tests/fleet_determinism.rs` heterogeneous fixture.
fn het_fleet() -> FleetConfig {
    let mut slow = arch();
    slow.bandwidth = 256;
    FleetConfig::new(vec![arch(), slow]).unwrap()
}

fn traffic(requests: u32) -> Vec<Request> {
    synthetic_traffic(
        &arch(),
        &TrafficConfig {
            requests,
            seed: 7,
            mean_gap_cycles: 2048,
            ..Default::default()
        },
    )
}

/// A fail-then-rejoin storm on chip 1, early enough to strand real
/// backlog and late enough that the rejoin still sees traffic.
fn storm() -> FaultPlan {
    FaultPlan::parse("fail@4000@1,join@60000@1").unwrap()
}

/// Policy-timeline CSVs — the fault-sensitive byte surface.
fn policy_csv(engine: &ServeEngine, reqs: &[Request]) -> String {
    let r = engine.run(reqs).unwrap();
    format!(
        "{}{}",
        r.fleet.to_table().to_csv(),
        r.fleet.requests_table().to_csv()
    )
}

/// The per-request reference timeline (`serve.csv`) — must never move,
/// faults or not.  (`serve_summary.csv` is *not* in this surface: its
/// availability/migration/redispatch columns report the policy
/// timeline's degraded state by design.)
fn reference_csv(engine: &ServeEngine, reqs: &[Request]) -> String {
    engine.run(reqs).unwrap().to_table().to_csv()
}

#[test]
fn faulted_reports_byte_identical_across_jobs() {
    let reqs = traffic(96);
    for policy in PlacementPolicy::ALL {
        let base = policy_csv(
            &ServeEngine::with_fleet(het_fleet(), policy, 1).with_faults(storm()),
            &reqs,
        );
        for jobs in [2usize, 8] {
            assert_eq!(
                base,
                policy_csv(
                    &ServeEngine::with_fleet(het_fleet(), policy, jobs).with_faults(storm()),
                    &reqs
                ),
                "policy {} diverged under faults at jobs={jobs}",
                policy.name()
            );
        }
    }
}

#[test]
fn empty_plan_reproduces_the_default_path_for_every_policy() {
    let reqs = traffic(64);
    for policy in PlacementPolicy::ALL {
        let plain = ServeEngine::with_fleet(het_fleet(), policy, 4);
        let gated = ServeEngine::with_fleet(het_fleet(), policy, 4)
            .with_faults(FaultPlan::none());
        assert_eq!(
            policy_csv(&plain, &reqs),
            policy_csv(&gated, &reqs),
            "policy {}: FaultPlan::none() must be byte-inert",
            policy.name()
        );
    }
}

#[test]
fn reference_csvs_are_fault_invariant() {
    let reqs = traffic(64);
    let base = reference_csv(
        &ServeEngine::with_fleet(het_fleet(), PlacementPolicy::RoundRobin, 4),
        &reqs,
    );
    for policy in PlacementPolicy::ALL {
        assert_eq!(
            base,
            reference_csv(
                &ServeEngine::with_fleet(het_fleet(), policy, 4).with_faults(storm()),
                &reqs
            ),
            "serve.csv/serve_summary.csv moved under faults (policy {})",
            policy.name()
        );
    }
}

#[test]
fn scripted_failure_run_serves_or_drops_every_request() {
    let reqs = traffic(96);
    let report = ServeEngine::with_fleet(het_fleet(), PlacementPolicy::LeastLoaded, 4)
        .with_faults(storm())
        .run(&reqs)
        .unwrap();
    let f = &report.fleet;

    // Complete accounting: every request is either served on a chip or
    // explicitly dropped and counted — nothing vanishes.
    assert_eq!(f.assignments.len(), reqs.len());
    let dropped = f.assignments.iter().filter(|a| a.dropped).count() as u32;
    assert_eq!(f.faults.dropped, dropped);
    for a in &f.assignments {
        if !a.dropped {
            assert!(a.chip < f.chips(), "served request names a real chip");
            assert!(a.service_cycles > 0);
        }
    }

    // The failure window shows up as availability < 1 on chip 1 only,
    // and stranded work was redispatched with real migration traffic.
    assert_eq!(f.availability(0), 1.0);
    assert!(f.availability(1) < 1.0, "chip 1 failed at cycle 4000");
    assert!(f.fleet_availability() < 1.0);
    assert!(f.faults.redispatched > 0, "the storm must strand backlog");
    assert!(f.faults.migration_bytes > 0);

    // Migration traffic is whole weight re-writes: the charged bytes
    // are an exact multiple of the macro footprint, at least one macro
    // per migrated request, and the re-write delay (priced through
    // `model::eqs::weight_write_cycles` by the engine) shows up as
    // positive redispatch latency.
    let migrated = f.assignments.iter().filter(|a| a.migrated && !a.dropped).count() as u64;
    assert!(migrated > 0);
    let size_macro = arch().geom.size_macro();
    assert_eq!(
        f.faults.migration_bytes % size_macro,
        0,
        "migration charges whole macros"
    );
    assert!(f.faults.migration_bytes >= migrated * size_macro);
    assert!(f.redispatch_mean_latency() > 0);
}

#[test]
fn autoscaler_grows_the_fleet_under_slo_pressure() {
    let reqs = traffic(64);
    let scale = AutoscaleConfig {
        slo_p99: 1,
        window: 8,
        min_chips: 1,
        cooldown: 1,
    };
    let run = || {
        ServeEngine::with_fleet(
            FleetConfig::homogeneous(arch(), 2),
            PlacementPolicy::LeastLoaded,
            4,
        )
        .with_autoscale(scale)
        .run(&reqs)
        .unwrap()
    };
    let report = run();
    let f = &report.fleet;
    assert!(f.faults.scale_ups >= 1, "slo_p99=1 must force growth");
    assert!(
        f.assignments.iter().any(|a| !a.dropped && a.chip == 1),
        "the joined chip must take traffic"
    );
    assert!(
        f.faults.migration_bytes > 0,
        "a scale-up pays the cold weight load"
    );
    // Deterministic: an identical run reproduces the same bytes.
    let again = run();
    assert_eq!(f.to_table().to_csv(), again.fleet.to_table().to_csv());
    assert_eq!(
        f.requests_table().to_csv(),
        again.fleet.requests_table().to_csv()
    );
}

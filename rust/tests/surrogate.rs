//! Surrogate serving end-to-end (ISSUE 7):
//!
//! - the streaming path ([`ServeEngine::run_traffic`]) is byte-identical
//!   to the materialized path (`run(&synthetic_traffic(..))`) for every
//!   placement policy, with and without fault injection — which, with
//!   `tests/fleet_determinism.rs` / `tests/fleet_faults.rs` pinning the
//!   dispatcher's semantics against coordinator-derived expectations,
//!   carries the event-heap timeline's legacy byte-identity;
//! - streaming reports are byte-identical across `--jobs`;
//! - a warm [`ServiceTimeTable`] replays a trace byte-identically without
//!   re-entering the simulator;
//! - `--surrogate eqs` agrees with exact calibration within 1% on every
//!   per-request service time;
//! - a 10⁶-request replay is deterministic across `--jobs`
//!   (env-gated: `GPP_SURROGATE_MILLION=1`, CI's surrogate smoke).
//!
//! [`ServeEngine::run_traffic`]: gpp_pim::serve::ServeEngine::run_traffic
//! [`ServiceTimeTable`]: gpp_pim::serve::ServiceTimeTable

use gpp_pim::arch::ArchConfig;
use gpp_pim::fleet::{FaultPlan, FleetConfig, PlacementPolicy};
use gpp_pim::serve::{
    synthetic_traffic, ServeEngine, ServeReport, ServiceTimeTable, SurrogateMode, TrafficConfig,
};
use std::sync::Arc;

fn arch() -> ArchConfig {
    ArchConfig::paper_default()
}

/// Two distinct archs (paper + half-bandwidth paper), as in
/// `tests/fleet_determinism.rs`.
fn het_fleet() -> FleetConfig {
    let mut slow = arch();
    slow.bandwidth = 256;
    FleetConfig::new(vec![arch(), slow]).unwrap()
}

fn cfg(requests: u32) -> TrafficConfig {
    TrafficConfig {
        requests,
        seed: 7,
        mean_gap_cycles: 2048,
        ..Default::default()
    }
}

/// Everything: reference CSVs + both policy-timeline CSVs.
fn full_csv(r: &ServeReport) -> String {
    format!(
        "{}{}{}{}",
        r.to_table().to_csv(),
        r.summary_table().to_csv(),
        r.fleet.to_table().to_csv(),
        r.fleet.requests_table().to_csv()
    )
}

#[test]
fn streaming_matches_materialized_for_every_policy_and_fault_plan() {
    let t = cfg(96);
    let reqs = synthetic_traffic(&arch(), &t);
    for policy in PlacementPolicy::ALL {
        for faults in ["", "mtbf@50000@9"] {
            let plan = if faults.is_empty() {
                FaultPlan::none()
            } else {
                FaultPlan::parse(faults).unwrap()
            };
            let engine = ServeEngine::with_fleet(het_fleet(), policy, 4).with_faults(plan);
            let direct = engine.run(&reqs).unwrap();
            let streamed = engine.run_traffic(&t).unwrap();
            assert_eq!(
                full_csv(&direct),
                full_csv(&streamed),
                "policy {} faults '{faults}'",
                policy.name()
            );
        }
    }
}

#[test]
fn streaming_reports_are_byte_identical_across_jobs() {
    let t = cfg(128);
    let run = |jobs| {
        full_csv(
            &ServeEngine::with_fleet(het_fleet(), PlacementPolicy::LeastLoaded, jobs)
                .run_traffic(&t)
                .unwrap(),
        )
    };
    let base = run(1);
    for jobs in [2usize, 8] {
        assert_eq!(base, run(jobs), "streaming run diverged at jobs={jobs}");
    }
}

#[test]
fn warm_table_replay_is_byte_identical_and_simulation_free() {
    let t = cfg(96);
    let table = Arc::new(ServiceTimeTable::new());
    let engine = ServeEngine::new(arch(), 2, 2).with_service_table(Arc::clone(&table));
    let cold = full_csv(&engine.run_traffic(&t).unwrap());
    let misses = table.misses();
    assert_eq!(misses as usize, table.len(), "one calibration per class");
    let warm = full_csv(&engine.run_traffic(&t).unwrap());
    assert_eq!(cold, warm, "warm replay changed the report bytes");
    assert_eq!(table.misses(), misses, "warm replay recalibrated a class");
}

#[test]
fn eqs_surrogate_agrees_with_exact_within_one_percent() {
    let t = cfg(192);
    let exact = ServeEngine::new(arch(), 4, 2).run_traffic(&t).unwrap();
    let eqs = ServeEngine::new(arch(), 4, 2)
        .with_surrogate(SurrogateMode::Eqs)
        .run_traffic(&t)
        .unwrap();
    assert_eq!(exact.surrogate, SurrogateMode::Exact);
    assert_eq!(eqs.surrogate, SurrogateMode::Eqs);
    assert_eq!(exact.records.len(), eqs.records.len());
    for (x, e) in exact.records.iter().zip(&eqs.records) {
        let err = x.service_cycles.abs_diff(e.service_cycles);
        assert!(
            err * 100 <= x.service_cycles,
            "request {}: eqs service {} vs exact {} (> 1%)",
            x.id,
            e.service_cycles,
            x.service_cycles
        );
    }
    // Prediction is conservative-by-construction: when the coverage map
    // declines every class, eqs degenerates to exact — bit for bit.
    if eqs.eqs_classes == 0 {
        assert_eq!(full_csv(&exact), full_csv(&eqs));
    }
}

#[test]
fn million_request_replay_is_deterministic_across_jobs() {
    // ~seconds of work: opt-in via GPP_SURROGATE_MILLION=1 (the CI
    // surrogate smoke sets it; plain `cargo test` skips).
    if std::env::var("GPP_SURROGATE_MILLION").ok().as_deref() != Some("1") {
        eprintln!("skipping million-request replay (set GPP_SURROGATE_MILLION=1)");
        return;
    }
    let t = TrafficConfig {
        requests: 1_000_000,
        seed: 11,
        mean_gap_cycles: 512,
        ..Default::default()
    };
    let a = ServeEngine::new(arch(), 1, 4).run_traffic(&t).unwrap();
    let b = ServeEngine::new(arch(), 8, 4).run_traffic(&t).unwrap();
    assert_eq!(a.requests(), 1_000_000);
    // Field-wise comparison: materializing two ~10⁶-row CSV strings per
    // report just to diff them would triple peak memory for no signal.
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(
            (x.id, x.arrival_cycle, x.queue_cycles, x.service_cycles, x.class),
            (y.id, y.arrival_cycle, y.queue_cycles, y.service_cycles, y.class)
        );
    }
    assert_eq!(a.fleet.assignments.len(), b.fleet.assignments.len());
    for (x, y) in a.fleet.assignments.iter().zip(&b.fleet.assignments) {
        assert_eq!(
            (x.id, x.chip, x.queue_cycles, x.service_cycles, x.migrated, x.dropped),
            (y.id, y.chip, y.queue_cycles, y.service_cycles, y.migrated, y.dropped)
        );
    }
    assert_eq!(a.summary_table().to_csv(), b.summary_table().to_csv());
    assert_eq!(a.fleet.to_table().to_csv(), b.fleet.to_table().to_csv());
}

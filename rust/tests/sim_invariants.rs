//! Integration: randomized invariant checks on the simulator itself —
//! the event-accelerated engine must behave exactly like a cycle-stepped
//! machine.  A tiny brute-force per-cycle reference simulator is built
//! here and compared against the engine on small random programs.

use gpp_pim::arch::ArchConfig;
use gpp_pim::isa::{Inst, Program};
use gpp_pim::sched::{SchedulePlan, Strategy};
use gpp_pim::sim::{simulate, OpKind, SimOptions};
use gpp_pim::util::rng::XorShift64;

/// Brute-force reference: step one cycle at a time for a *single-stream*
/// program with one macro — enough to pin the engine's write/compute/bus
/// arithmetic bit-exactly.
fn brute_force_single_macro(arch: &ArchConfig, insts: &[Inst]) -> u64 {
    let mut now: u64 = 0;
    let mut pc = 0usize;
    let mut loop_stack: Vec<(usize, u32)> = Vec::new();
    let mut write_left: u64 = 0;
    let mut compute_left: u64 = 0;
    let mut speed = arch.write_speed as u64;
    let mut sleep_until: u64 = 0;
    loop {
        // Issue as much as possible at the current cycle.
        loop {
            if now < sleep_until {
                break;
            }
            match insts.get(pc) {
                None => return now,
                Some(Inst::Halt) => {
                    // Drain in-flight ops.
                    while write_left > 0 || compute_left > 0 {
                        now += 1;
                        let rate = speed.min(arch.bandwidth);
                        write_left = write_left.saturating_sub(rate);
                        compute_left = compute_left.saturating_sub(1);
                    }
                    return now;
                }
                Some(Inst::SetSpd { speed: s }) => {
                    speed = *s as u64;
                    pc += 1;
                }
                Some(Inst::Delay { cycles }) => {
                    sleep_until = now + *cycles as u64;
                    pc += 1;
                    break;
                }
                Some(Inst::Wrw { .. }) => {
                    assert_eq!(write_left, 0);
                    write_left = arch.geom.size_macro();
                    pc += 1;
                }
                Some(Inst::Vmm { n_vec, .. }) => {
                    assert_eq!(compute_left, 0);
                    compute_left = arch.geom.cycles_per_vector() * *n_vec as u64;
                    pc += 1;
                }
                Some(Inst::WaitW { .. }) => {
                    if write_left == 0 {
                        pc += 1;
                    } else {
                        break;
                    }
                }
                Some(Inst::WaitC { .. }) => {
                    if compute_left == 0 {
                        pc += 1;
                    } else {
                        break;
                    }
                }
                Some(Inst::LdIn { .. }) | Some(Inst::StOut { .. }) => pc += 1,
                Some(Inst::Barrier) => pc += 1, // single stream: no-op
                Some(Inst::Loop { count }) => {
                    loop_stack.push((pc, *count));
                    pc += 1;
                }
                Some(Inst::EndLoop) => {
                    let (start, rem) = loop_stack.pop().unwrap();
                    if rem > 1 {
                        loop_stack.push((start, rem - 1));
                        pc = start + 1;
                    } else {
                        pc += 1;
                    }
                }
            }
        }
        // One cycle of progress.
        now += 1;
        let rate = if write_left > 0 {
            speed.min(arch.bandwidth)
        } else {
            0
        };
        write_left = write_left.saturating_sub(rate);
        compute_left = compute_left.saturating_sub(1);
    }
}

fn random_single_macro_program(rng: &mut XorShift64) -> Vec<Inst> {
    let mut insts = vec![Inst::SetSpd {
        speed: rng.range_i64(1, 8) as u16,
    }];
    let blocks = rng.range_i64(1, 6);
    for b in 0..blocks {
        if rng.next_below(3) == 0 {
            insts.push(Inst::Delay {
                cycles: rng.range_i64(0, 300) as u32,
            });
        }
        insts.push(Inst::Wrw {
            m: 0,
            tile: b as u32 + 1,
        });
        insts.push(Inst::WaitW { m: 0 });
        insts.push(Inst::Vmm {
            m: 0,
            n_vec: rng.range_i64(1, 12) as u16,
            tile: b as u32 + 1,
        });
        insts.push(Inst::WaitC { m: 0 });
    }
    insts.push(Inst::Halt);
    insts
}

#[test]
fn engine_matches_brute_force_cycle_stepper() {
    let mut rng = XorShift64::new(0x5EED);
    for case in 0..60 {
        let mut arch = ArchConfig::paper_default();
        arch.bandwidth = 1 << rng.range_i64(0, 6); // 1..64 B/cyc
        arch.core_buffer_bytes = 1 << 22;
        let insts = random_single_macro_program(&mut rng);
        let brute = brute_force_single_macro(&arch, &insts);
        let mut program = Program::new(16);
        program.add_stream(0, insts.clone());
        let engine = simulate(&arch, &program, SimOptions::default())
            .unwrap()
            .stats
            .cycles;
        assert_eq!(engine, brute, "case {case}: {insts:?} band={}", arch.bandwidth);
    }
}

#[test]
fn op_log_is_consistent() {
    // Every logged op has start < end; per-macro ops never overlap
    // (without intra-macro mode); totals match the counters.
    let mut rng = XorShift64::new(0xFACE);
    for _ in 0..10 {
        let mut arch = ArchConfig::paper_default();
        arch.bandwidth = 1 << rng.range_i64(3, 9);
        arch.core_buffer_bytes = 1 << 22;
        let plan = SchedulePlan {
            tasks: rng.range_i64(10, 120) as u32,
            active_macros: rng.range_i64(2, 32) as u32,
            n_in: rng.range_i64(1, 8) as u32,
            write_speed: rng.range_i64(1, 8) as u32,
        };
        let strategy = match rng.next_below(3) {
            0 => Strategy::InSitu,
            1 => Strategy::NaivePingPong,
            _ => Strategy::GeneralizedPingPong,
        };
        let program = strategy.codegen(&arch, &plan).unwrap();
        let result = simulate(
            &arch,
            &program,
            SimOptions {
                record_op_log: true,
                ..SimOptions::default()
            },
        )
        .unwrap();
        let writes = result
            .op_log
            .iter()
            .filter(|o| o.kind == OpKind::Write)
            .count() as u64;
        let computes = result
            .op_log
            .iter()
            .filter(|o| o.kind == OpKind::Compute)
            .count() as u64;
        assert_eq!(writes, result.stats.writes_completed);
        assert_eq!(computes, result.stats.vmms_completed);
        // Ops on the same macro must not overlap in time.
        let mut by_macro: std::collections::HashMap<u32, Vec<(u64, u64)>> = Default::default();
        for op in &result.op_log {
            by_macro
                .entry(op.global_macro(arch.macros_per_core))
                .or_default()
                .push((op.start, op.end));
        }
        for (g, mut spans) in by_macro {
            spans.sort_unstable();
            for w in spans.windows(2) {
                assert!(
                    w[0].1 <= w[1].0,
                    "macro {g}: overlapping ops {:?} {:?} ({strategy:?})",
                    w[0],
                    w[1]
                );
            }
        }
        // All ops end within the measured total.
        assert!(result
            .op_log
            .iter()
            .all(|o| o.start < o.end && o.end <= result.stats.cycles));
    }
}

#[test]
fn stats_integrals_bounded() {
    // write+compute cycles per macro never exceed total cycles; bus busy
    // <= cycles; peak rate <= band.
    let mut rng = XorShift64::new(0xBEEF);
    for _ in 0..12 {
        let mut arch = ArchConfig::paper_default();
        arch.bandwidth = 1 << rng.range_i64(2, 9);
        arch.core_buffer_bytes = 1 << 22;
        let plan = SchedulePlan {
            tasks: rng.range_i64(5, 150) as u32,
            active_macros: rng.range_i64(1, 48) as u32,
            n_in: rng.range_i64(1, 10) as u32,
            write_speed: rng.range_i64(1, 8) as u32,
        };
        for strategy in Strategy::ALL {
            let program = strategy.codegen(&arch, &plan).unwrap();
            let stats = simulate(&arch, &program, SimOptions::default())
                .unwrap()
                .stats;
            assert!(stats.bus_busy_cycles <= stats.cycles);
            assert!(stats.peak_bus_rate <= arch.bandwidth);
            for g in 0..stats.macro_write_cycles.len() {
                assert!(
                    stats.macro_write_cycles[g] + stats.macro_compute_cycles[g] <= stats.cycles,
                    "{strategy:?} macro {g}"
                );
            }
            for core in 0..arch.n_cores as usize {
                assert!(
                    stats.buffer_integral[core]
                        <= arch.core_buffer_bytes as u128 * stats.cycles as u128
                );
                assert!(stats.buffer_peak[core] <= arch.core_buffer_bytes);
            }
        }
    }
}

#[test]
fn issue_cost_monotone() {
    // Adding per-instruction issue cost can only slow execution down.
    let arch = ArchConfig::paper_default();
    let plan = SchedulePlan {
        tasks: 32,
        active_macros: 8,
        n_in: 4,
        write_speed: 8,
    };
    for strategy in Strategy::ALL {
        let program = strategy.codegen(&arch, &plan).unwrap();
        let free = simulate(&arch, &program, SimOptions::default())
            .unwrap()
            .stats
            .cycles;
        let costed = simulate(
            &arch,
            &program,
            SimOptions {
                issue_cost: 2,
                ..SimOptions::default()
            },
        )
        .unwrap()
        .stats
        .cycles;
        assert!(costed >= free, "{strategy:?}: {costed} < {free}");
    }
}

#[test]
fn intra_macro_overlap_strictly_faster() {
    // With intra-macro ping-pong the same per-macro program overlaps
    // write and compute: wall-clock must shrink for a write+compute loop.
    let arch = ArchConfig::paper_default();
    let mut program = Program::new(16);
    // write(k+1) issued while compute(k) runs — legal only with overlap.
    let mut insts = vec![
        Inst::Wrw { m: 0, tile: 1 },
        Inst::WaitW { m: 0 },
    ];
    for k in 1..6u32 {
        insts.push(Inst::Vmm {
            m: 0,
            n_vec: 4,
            tile: k,
        });
        insts.push(Inst::Wrw { m: 0, tile: k + 1 });
        insts.push(Inst::WaitC { m: 0 });
        insts.push(Inst::WaitW { m: 0 });
    }
    insts.push(Inst::Halt);
    program.add_stream(0, insts);
    let overlapped = simulate(
        &arch,
        &program,
        SimOptions {
            allow_intra_overlap: true,
            ..SimOptions::default()
        },
    )
    .unwrap()
    .stats
    .cycles;
    // Serial equivalent: 128 + 5 * (128 + 128).
    assert_eq!(overlapped, 128 + 5 * 128);
}

#[test]
fn dynamic_bandwidth_schedule_applies() {
    // A mid-run bandwidth cut must stretch writes after the step: one
    // macro writing 4 tiles back-to-back at s=8, band drops 8 -> 2 at
    // cycle 256 (after two writes' worth of bytes... writes interleave
    // with computes, so the cut lands mid-stream).
    let mut arch = ArchConfig::paper_default();
    arch.bandwidth = 8;
    let mut program = Program::new(16);
    let mut insts = Vec::new();
    for k in 1..=4u32 {
        insts.push(Inst::Wrw { m: 0, tile: k });
        insts.push(Inst::WaitW { m: 0 });
    }
    insts.push(Inst::Halt);
    program.add_stream(0, insts);

    let steady = simulate(&arch, &program, SimOptions::default())
        .unwrap()
        .stats
        .cycles;
    assert_eq!(steady, 4 * 128);

    let opts = SimOptions {
        bandwidth_schedule: vec![(256, 2)],
        ..SimOptions::default()
    };
    let stepped = simulate(&arch, &program, opts).unwrap().stats.cycles;
    // First two writes at 8 B/cyc (256 cycles), last two at 2 B/cyc
    // (512 cycles each).
    assert_eq!(stepped, 256 + 2 * 512);
}

#[test]
fn dynamic_bandwidth_restores() {
    // Drop and restore: 8 -> 1 during [128, 640) -> 8.
    let mut arch = ArchConfig::paper_default();
    arch.bandwidth = 8;
    let mut program = Program::new(16);
    program.add_stream(
        0,
        vec![
            Inst::Wrw { m: 0, tile: 1 },
            Inst::WaitW { m: 0 },
            Inst::Wrw { m: 0, tile: 2 },
            Inst::WaitW { m: 0 },
            Inst::Halt,
        ],
    );
    let opts = SimOptions {
        bandwidth_schedule: vec![(128, 1), (640, 8)],
        ..SimOptions::default()
    };
    let cycles = simulate(&arch, &program, opts).unwrap().stats.cycles;
    // Write 1: cycles 0..128 at 8 B/cyc. Write 2: 512 cycles at 1 B/cyc
    // moves 512 B (cycles 128..640), remaining 512 B at 8 B/cyc = 64.
    assert_eq!(cycles, 128 + 512 + 64);
}

#[test]
fn unsorted_bandwidth_schedule_rejected() {
    let arch = ArchConfig::paper_default();
    let mut program = Program::new(16);
    program.add_stream(0, vec![Inst::Halt]);
    let opts = SimOptions {
        bandwidth_schedule: vec![(100, 4), (50, 8)],
        ..SimOptions::default()
    };
    assert!(simulate(&arch, &program, opts).is_err());
}

/// One macro writing `n` tiles back-to-back (the bandwidth-schedule
/// edge-case workload).
fn back_to_back_writes(n: u32) -> Program {
    let mut program = Program::new(16);
    let mut insts = Vec::new();
    for k in 1..=n {
        insts.push(Inst::Wrw { m: 0, tile: k });
        insts.push(Inst::WaitW { m: 0 });
    }
    insts.push(Inst::Halt);
    program.add_stream(0, insts);
    program
}

#[test]
fn bandwidth_step_at_cycle_zero_applies_from_start() {
    // A (0, band) step must override the configured bandwidth before any
    // byte moves: s=8 capped by band=2 from cycle 0 -> 512 cycles/tile.
    let mut arch = ArchConfig::paper_default();
    arch.bandwidth = 8;
    let opts = SimOptions {
        bandwidth_schedule: vec![(0, 2)],
        ..SimOptions::default()
    };
    let r = simulate(&arch, &back_to_back_writes(2), opts).unwrap();
    assert_eq!(r.stats.cycles, 2 * 512);
    assert_eq!(r.stats.peak_bus_rate, 2);
}

#[test]
fn bandwidth_step_past_completion_is_ignored() {
    // A step far beyond the program's end must neither stall the run nor
    // change its timing.
    let mut arch = ArchConfig::paper_default();
    arch.bandwidth = 8;
    let steady = simulate(&arch, &back_to_back_writes(4), SimOptions::default())
        .unwrap()
        .stats
        .cycles;
    let opts = SimOptions {
        bandwidth_schedule: vec![(1_000_000_000, 1)],
        ..SimOptions::default()
    };
    let stepped = simulate(&arch, &back_to_back_writes(4), opts).unwrap();
    assert_eq!(stepped.stats.cycles, steady);
    assert_eq!(stepped.stats.peak_bus_rate, 8);
}

#[test]
fn bandwidth_steps_at_equal_cycle_last_wins() {
    // Equal-cycle entries are legal ("sorted" is non-strict); they apply
    // in order, so the last one is in effect.
    let mut arch = ArchConfig::paper_default();
    arch.bandwidth = 8;
    let opts = SimOptions {
        bandwidth_schedule: vec![(128, 1), (128, 4)],
        ..SimOptions::default()
    };
    let r = simulate(&arch, &back_to_back_writes(2), opts).unwrap();
    // Tile 1: 128 cycles at 8 B/cyc; tile 2: 1024 B at 4 B/cyc = 256.
    assert_eq!(r.stats.cycles, 128 + 256);
}

#[test]
fn bandwidth_step_to_zero_then_restore() {
    // band -> 0 freezes the bus (no deadlock: the next schedule step is a
    // pending event) until the restoring step arrives.
    let mut arch = ArchConfig::paper_default();
    arch.bandwidth = 8;
    let opts = SimOptions {
        bandwidth_schedule: vec![(64, 0), (1064, 8)],
        ..SimOptions::default()
    };
    let r = simulate(&arch, &back_to_back_writes(1), opts).unwrap();
    // 64 cycles at 8 B/cyc (512 B), 1000 frozen, rest at 8 B/cyc (64).
    assert_eq!(r.stats.cycles, 64 + 1000 + 64);
    assert_eq!(r.stats.bus_bytes, 1024);
}

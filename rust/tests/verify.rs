//! Integration: the static schedule verifier against the engine.
//!
//! Three agreement properties, mirroring what `gpp-pim check` certifies:
//!
//! 1. Every shipped lowering verifies clean across the paper's sweep
//!    axes (the Fig. 4 write-speed axis, the Fig. 6 bandwidth axis).
//! 2. Everything the verifier certifies simulates panic-free, and the
//!    certified analytic lower bound never exceeds the measured cycles.
//! 3. Every seeded defect class from the mutation harness is caught
//!    with a diagnostic that locates the offending instruction.

use gpp_pim::analysis::mutate::mutate;
use gpp_pim::analysis::{verify_program, MutationClass, VerifyOptions};
use gpp_pim::arch::ArchConfig;
use gpp_pim::sched::{CodegenStyle, SchedulePlan, Strategy};
use gpp_pim::sim::simulate;

const STYLES: [CodegenStyle; 2] = [CodegenStyle::Unrolled, CodegenStyle::Looped];

/// Verify, then simulate, then certify the lower bound — the full
/// `check` contract for one cell.
fn certify_cell(arch: &ArchConfig, strategy: Strategy, style: CodegenStyle, plan: &SchedulePlan) {
    let program = strategy
        .codegen_styled(arch, plan, style)
        .expect("shipped lowering must be feasible");
    let mut report = verify_program(arch, &program, &VerifyOptions::for_strategy(strategy));
    assert!(
        report.ok(),
        "{strategy:?}/{style:?} {plan:?}: {}",
        report.first_error().unwrap()
    );
    let cycles = simulate(arch, &program, strategy.sim_options())
        .expect("certified program must simulate panic-free")
        .stats
        .cycles;
    assert!(
        report.certify_cycles(cycles),
        "{strategy:?}/{style:?} {plan:?}: bound {} > sim {cycles}",
        report.lower_bound_cycles
    );
}

#[test]
fn fig4_write_speed_axis_certifies_clean() {
    // The Fig. 4 experiment sweeps the weight-write speed s; every
    // strategy/style lowering along that axis must verify and certify.
    let mut arch = ArchConfig::fig4_default();
    for s in 1..=8u32 {
        arch.write_speed = s;
        let plan = SchedulePlan {
            tasks: 24,
            active_macros: 8,
            n_in: arch.n_in,
            write_speed: s,
        };
        for strategy in Strategy::ALL_EXTENDED {
            for style in STYLES {
                certify_cell(&arch, strategy, style, &plan);
            }
        }
    }
}

#[test]
fn fig6_bandwidth_axis_certifies_clean() {
    // The Fig. 6 experiment sweeps off-chip bandwidth (and with it the
    // tr:tp balance); certify the grid of bandwidth x n_in points.
    for band in [64u64, 128, 256, 512, 1024] {
        for n_in in [1u32, 4, 8] {
            let mut arch = ArchConfig::paper_default();
            arch.bandwidth = band;
            let plan = SchedulePlan {
                tasks: 24,
                active_macros: 8,
                n_in,
                write_speed: arch.write_speed,
            };
            for strategy in Strategy::ALL_EXTENDED {
                for style in STYLES {
                    certify_cell(&arch, strategy, style, &plan);
                }
            }
        }
    }
}

#[test]
fn every_mutation_class_is_caught_with_a_located_diagnostic() {
    // Seed each defect class into every applicable shipped lowering:
    // the verifier must reject the mutant, and the diagnostic must name
    // the core/stream (and, site-carrying variants, the offset and
    // mnemonic) so the defect is findable without a waveform.
    let arch = ArchConfig::paper_default();
    let plan = SchedulePlan {
        tasks: 24,
        active_macros: 8,
        n_in: arch.n_in,
        write_speed: arch.write_speed,
    };
    for class in MutationClass::ALL {
        let mut applied = 0usize;
        for strategy in Strategy::ALL_EXTENDED {
            for style in STYLES {
                let pristine = strategy.codegen_styled(&arch, &plan, style).unwrap();
                let Some(mutant) = mutate(&pristine, class, 7) else {
                    continue;
                };
                applied += 1;
                let report = verify_program(&arch, &mutant, &VerifyOptions::for_strategy(strategy));
                let err = report.first_error().unwrap_or_else(|| {
                    panic!("{class:?} on {strategy:?}/{style:?} escaped the verifier")
                });
                let text = err.to_string();
                assert!(
                    text.contains("core "),
                    "{class:?} diagnostic does not locate the defect: {text}"
                );
            }
        }
        assert!(applied > 0, "{class:?} applied nowhere in the grid");
    }
}

#[test]
fn pristine_lowerings_survive_their_own_mutation_seeds() {
    // Sanity on the harness itself: mutation returns a *different*
    // program (otherwise a "caught" defect could be a verifier false
    // positive on the original).
    let arch = ArchConfig::paper_default();
    let plan = SchedulePlan {
        tasks: 24,
        active_macros: 8,
        n_in: arch.n_in,
        write_speed: arch.write_speed,
    };
    for class in MutationClass::ALL {
        for strategy in Strategy::ALL_EXTENDED {
            let pristine = strategy
                .codegen_styled(&arch, &plan, CodegenStyle::Unrolled)
                .unwrap();
            if let Some(mutant) = mutate(&pristine, class, 7) {
                assert_ne!(mutant, pristine, "{class:?} on {strategy:?} was a no-op");
            }
        }
    }
}

//! Integration: the analytical model (paper Eqs. 1–9, "theory") against
//! the cycle-accurate simulator ("practice") across randomized
//! configurations — the same agreement the paper demonstrates in Table II,
//! checked as properties with a deterministic RNG (no proptest offline).

use gpp_pim::arch::ArchConfig;
use gpp_pim::model::eqs;
use gpp_pim::sched::{SchedulePlan, Strategy};
use gpp_pim::sim::{simulate, SimOptions};
use gpp_pim::util::rng::XorShift64;

fn sim_cycles(arch: &ArchConfig, strategy: Strategy, plan: &SchedulePlan) -> u64 {
    let program = strategy.codegen(arch, plan).expect("codegen");
    simulate(arch, &program, SimOptions::default())
        .expect("simulate")
        .stats
        .cycles
}

/// Random (s, n_in) working points with ample bandwidth.
fn random_points(seed: u64, count: usize) -> Vec<(u32, u32)> {
    let mut rng = XorShift64::new(seed);
    (0..count)
        .map(|_| {
            let s = rng.range_i64(1, 8) as u32;
            let n_in = rng.range_i64(1, 16) as u32;
            (s, n_in)
        })
        .collect()
}

#[test]
fn naive_utilization_matches_eq1_eq2() {
    // Long steady-state naive ping-pong runs hit the Eq. 1/2 utilization
    // within the startup/drain tail for every random working point.
    let mut arch = ArchConfig::paper_default();
    arch.bandwidth = 4096;
    arch.core_buffer_bytes = 1 << 22;
    for (s, n_in) in random_points(11, 12) {
        let plan = SchedulePlan {
            tasks: 128,
            active_macros: 2,
            n_in,
            write_speed: s,
        };
        let program = Strategy::NaivePingPong.codegen(&arch, &plan).unwrap();
        let stats = simulate(&arch, &program, SimOptions::default())
            .unwrap()
            .stats;
        let tp = arch.time_pim_at(n_in) as f64;
        let tr = arch.time_rewrite_at(s) as f64;
        let model = eqs::naive_pingpong_util(tp, tr);
        let sim = stats.macro_utilization_active();
        assert!(
            (model - sim).abs() < 0.06,
            "s={s} n_in={n_in}: model {model:.3} vs sim {sim:.3}"
        );
    }
}

#[test]
fn gpp_macro_utilization_is_full() {
    // GPP never idles a macro (modulo startup stagger + final drain).
    let mut arch = ArchConfig::paper_default();
    arch.bandwidth = 4096;
    arch.core_buffer_bytes = 1 << 22;
    for (s, n_in) in random_points(13, 10) {
        let plan = SchedulePlan {
            tasks: 256,
            active_macros: 4,
            n_in,
            write_speed: s,
        };
        let program = Strategy::GeneralizedPingPong.codegen(&arch, &plan).unwrap();
        let stats = simulate(&arch, &program, SimOptions::default())
            .unwrap()
            .stats;
        let util = stats.macro_utilization_active();
        assert!(util > 0.93, "s={s} n_in={n_in}: util {util:.3}");
    }
}

#[test]
fn insitu_period_is_exactly_tr_plus_tp() {
    // With bandwidth >= active*s the in-situ round takes tr + tp exactly.
    let mut arch = ArchConfig::paper_default();
    arch.bandwidth = 1 << 16;
    arch.core_buffer_bytes = 1 << 22;
    for (s, n_in) in random_points(17, 10) {
        let plan = SchedulePlan {
            tasks: 64,
            active_macros: 16,
            n_in,
            write_speed: s,
        };
        let rounds = plan.tasks.div_ceil(plan.active_macros) as u64;
        let expect = rounds * (arch.time_rewrite_at(s) + arch.time_pim_at(n_in));
        let got = sim_cycles(&arch, Strategy::InSitu, &plan);
        assert_eq!(got, expect, "s={s} n_in={n_in}");
    }
}

#[test]
fn strategy_ordering_compute_heavy() {
    // tp > tr with bandwidth at the GPP average: gpp <= naive <= insitu
    // (the Fig. 6 left half), across random compute-heavy points.
    let mut rng = XorShift64::new(23);
    for _ in 0..8 {
        let n_in = rng.range_i64(8, 32) as u32; // tp = 32*n_in >= 256
        let s = 8u32; // tr = 128
        let mut arch = ArchConfig::paper_default();
        arch.core_buffer_bytes = 1 << 22;
        let active = 16u32;
        let tp = arch.time_pim_at(n_in) as f64;
        let tr = arch.time_rewrite_at(s) as f64;
        // Bandwidth that exactly sustains GPP's staggered writes.
        arch.bandwidth = ((active as f64) * tr / (tp + tr) * s as f64).ceil() as u64;
        let plan = SchedulePlan {
            tasks: 256,
            active_macros: active,
            n_in,
            write_speed: s,
        };
        let gpp = sim_cycles(&arch, Strategy::GeneralizedPingPong, &plan);
        let naive = sim_cycles(&arch, Strategy::NaivePingPong, &plan);
        let insitu = sim_cycles(&arch, Strategy::InSitu, &plan);
        assert!(gpp <= naive + naive / 20, "n_in={n_in}: gpp {gpp} naive {naive}");
        assert!(naive <= insitu + insitu / 20, "n_in={n_in}: naive {naive} insitu {insitu}");
    }
}

#[test]
fn all_strategies_complete_all_work() {
    // Conservation: every strategy computes exactly the planned vectors
    // and writes exactly tasks * size_macro bytes.
    let mut rng = XorShift64::new(31);
    for _ in 0..10 {
        let mut arch = ArchConfig::paper_default();
        arch.core_buffer_bytes = 1 << 22;
        arch.bandwidth = 1 << rng.range_i64(3, 10) as u64;
        let plan = SchedulePlan {
            tasks: rng.range_i64(1, 300) as u32,
            active_macros: rng.range_i64(1, 64) as u32,
            n_in: rng.range_i64(1, 12) as u32,
            write_speed: rng.range_i64(1, 8) as u32,
        };
        for strategy in Strategy::ALL {
            let program = strategy.codegen(&arch, &plan).unwrap();
            let stats = simulate(&arch, &program, SimOptions::default())
                .unwrap()
                .stats;
            assert_eq!(stats.vmms_completed, plan.tasks as u64, "{strategy:?} {plan:?}");
            assert_eq!(
                stats.vectors_computed,
                plan.tasks as u64 * plan.n_in as u64,
                "{strategy:?}"
            );
            assert_eq!(
                stats.bus_bytes,
                plan.tasks as u64 * arch.geom.size_macro(),
                "{strategy:?}"
            );
        }
    }
}

#[test]
fn gpp_peak_bandwidth_below_insitu() {
    // Fig. 3's point: GPP's peak bus demand is a fraction of in-situ's.
    let mut arch = ArchConfig::paper_default();
    arch.bandwidth = 4096; // ample so peaks are strategy-intrinsic
    arch.core_buffer_bytes = 1 << 22;
    let plan = SchedulePlan {
        tasks: 128,
        active_macros: 16,
        n_in: 12, // tp = 3 tr
        write_speed: 8,
    };
    let peak = |s: Strategy| {
        let program = s.codegen(&arch, &plan).unwrap();
        simulate(&arch, &program, SimOptions::default())
            .unwrap()
            .stats
            .peak_bus_rate
    };
    let gpp = peak(Strategy::GeneralizedPingPong);
    let naive = peak(Strategy::NaivePingPong);
    let insitu = peak(Strategy::InSitu);
    assert!(gpp < naive, "gpp {gpp} naive {naive}");
    assert!(naive <= insitu, "naive {naive} insitu {insitu}");
    // tr/(tp+tr) = 1/4 of the macros write at once: peak = 4 * 8 = 32,
    // plus at most one extra writer during phase boundaries.
    assert!(gpp <= 5 * 8, "gpp peak {gpp}");
    assert_eq!(insitu, 16 * 8);
}

#[test]
fn verifier_lower_bound_never_exceeds_simulated_cycles() {
    // Theory vs practice for the static verifier: the analytic lower
    // bound it certifies (write traffic / bandwidth ⊔ per-core compute)
    // must never exceed the cycle count the engine actually measures —
    // for every strategy, both codegen styles, random working points.
    use gpp_pim::analysis::{verify_program, VerifyOptions};
    use gpp_pim::sched::CodegenStyle;
    let mut rng = XorShift64::new(41);
    for _ in 0..8 {
        let mut arch = ArchConfig::paper_default();
        arch.core_buffer_bytes = 1 << 22;
        arch.bandwidth = 1 << rng.range_i64(3, 10) as u64;
        let plan = SchedulePlan {
            tasks: rng.range_i64(1, 200) as u32,
            active_macros: rng.range_i64(1, 64) as u32,
            n_in: rng.range_i64(1, 12) as u32,
            write_speed: rng.range_i64(1, 8) as u32,
        };
        for strategy in Strategy::ALL_EXTENDED {
            for style in [CodegenStyle::Unrolled, CodegenStyle::Looped] {
                let program = strategy.codegen_styled(&arch, &plan, style).unwrap();
                let mut report =
                    verify_program(&arch, &program, &VerifyOptions::for_strategy(strategy));
                assert!(
                    report.ok(),
                    "{strategy:?}/{style:?} {plan:?}: {}",
                    report.first_error().unwrap()
                );
                let cycles = simulate(&arch, &program, strategy.sim_options())
                    .unwrap()
                    .stats
                    .cycles;
                assert!(
                    report.certify_cycles(cycles),
                    "{strategy:?}/{style:?} {plan:?}: bound {} > sim {cycles}",
                    report.lower_bound_cycles
                );
            }
        }
    }
}

#[test]
fn eq4_bandwidth_sizing_saturates_bus() {
    // Size the macro count by Eq. 4, give exactly `band`: the simulated
    // bus utilization should be ~100% during the steady state.
    let mut arch = ArchConfig::paper_default();
    arch.core_buffer_bytes = 1 << 22;
    arch.bandwidth = 32;
    let (s, n_in) = (8u32, 12u32); // tr=128, tp=384
    let tp = arch.time_pim_at(n_in) as f64;
    let tr = arch.time_rewrite_at(s) as f64;
    let active = eqs::num_macros_gpp(tp, tr, arch.bandwidth as f64, s as f64).round() as u32;
    assert_eq!(active, 16);
    let plan = SchedulePlan {
        tasks: 512,
        active_macros: active,
        n_in,
        write_speed: s,
    };
    let program = Strategy::GeneralizedPingPong.codegen(&arch, &plan).unwrap();
    let stats = simulate(&arch, &program, SimOptions::default())
        .unwrap()
        .stats;
    let util = stats.bandwidth_utilization(arch.bandwidth);
    assert!(util > 0.90, "bus util {util:.3}");
}

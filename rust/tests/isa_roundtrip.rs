//! Integration: the ISA toolchain round-trips — strategy codegen output
//! survives disassemble→assemble and encode→decode unchanged, including
//! randomized programs (hand-rolled property tests, deterministic seeds).

use gpp_pim::arch::ArchConfig;
use gpp_pim::isa::{assemble, decode_program, disassemble, encode_program, Inst, Program};
use gpp_pim::sched::{SchedulePlan, Strategy};
use gpp_pim::util::rng::XorShift64;

#[test]
fn strategy_programs_roundtrip_text() {
    let arch = ArchConfig::paper_default();
    let plan = SchedulePlan {
        tasks: 48,
        active_macros: 24,
        n_in: 4,
        write_speed: 8,
    };
    for strategy in Strategy::ALL {
        let program = strategy.codegen(&arch, &plan).unwrap();
        let text = disassemble(&program);
        let back = assemble(&text).unwrap();
        assert_eq!(back, program, "{strategy:?} text roundtrip");
    }
}

#[test]
fn strategy_programs_roundtrip_binary() {
    let arch = ArchConfig::paper_default();
    let plan = SchedulePlan {
        tasks: 31, // ragged on purpose
        active_macros: 7,
        n_in: 3,
        write_speed: 5,
    };
    for strategy in Strategy::ALL {
        let program = strategy.codegen(&arch, &plan).unwrap();
        let words = encode_program(&program);
        let back = decode_program(&words).unwrap();
        assert_eq!(back, program, "{strategy:?} binary roundtrip");
    }
}

fn random_inst(rng: &mut XorShift64) -> Inst {
    match rng.next_below(10) {
        0 => Inst::SetSpd {
            speed: rng.range_i64(1, 8) as u16,
        },
        1 => Inst::Delay {
            cycles: rng.range_i64(0, 10_000) as u32,
        },
        2 => Inst::Wrw {
            m: rng.range_i64(0, 15) as u8,
            tile: rng.range_i64(0, 1 << 20) as u32,
        },
        3 => Inst::Vmm {
            m: rng.range_i64(0, 15) as u8,
            n_vec: rng.range_i64(1, 64) as u16,
            tile: rng.range_i64(0, 1 << 20) as u32,
        },
        4 => Inst::WaitW {
            m: rng.range_i64(0, 15) as u8,
        },
        5 => Inst::WaitC {
            m: rng.range_i64(0, 15) as u8,
        },
        6 => Inst::LdIn {
            n_vec: rng.range_i64(1, 64) as u16,
        },
        7 => Inst::StOut {
            n_vec: rng.range_i64(1, 64) as u16,
        },
        8 => Inst::Barrier,
        _ => Inst::Halt,
    }
}

/// Property: arbitrary (even invalid-to-execute) programs round-trip the
/// encoders byte-exactly.
#[test]
fn random_programs_roundtrip_binary_and_text() {
    let mut rng = XorShift64::new(0xA11CE);
    for case in 0..50 {
        let n_streams = rng.range_i64(1, 6) as usize;
        let mut program = Program::new(16);
        for _ in 0..n_streams {
            let core = rng.range_i64(0, 15) as u32;
            let len = rng.range_i64(1, 40) as usize;
            let mut insts: Vec<Inst> = (0..len).map(|_| random_inst(&mut rng)).collect();
            // Strip structure-breaking loop tokens, then close with halt:
            // loops are exercised separately below.
            insts.retain(|i| !matches!(i, Inst::Loop { .. } | Inst::EndLoop));
            insts.push(Inst::Halt);
            program.add_stream(core, insts);
        }
        let words = encode_program(&program);
        assert_eq!(decode_program(&words).unwrap(), program, "case {case} binary");
        let text = disassemble(&program);
        assert_eq!(assemble(&text).unwrap(), program, "case {case} text");
    }
}

/// Property: random *balanced* loop nests round-trip and validate.
#[test]
fn random_loop_nests_roundtrip() {
    let mut rng = XorShift64::new(0xB0B);
    for case in 0..30 {
        let mut insts = Vec::new();
        let depth_budget = rng.range_i64(1, 4);
        fn emit(rng: &mut XorShift64, insts: &mut Vec<Inst>, depth: i64) {
            let body = rng.range_i64(1, 4);
            for _ in 0..body {
                if depth > 0 && rng.next_below(2) == 0 {
                    insts.push(Inst::Loop {
                        count: rng.range_i64(1, 5) as u32,
                    });
                    emit(rng, insts, depth - 1);
                    insts.push(Inst::EndLoop);
                } else {
                    insts.push(Inst::Delay {
                        cycles: rng.range_i64(1, 10) as u32,
                    });
                }
            }
        }
        emit(&mut rng, &mut insts, depth_budget);
        insts.push(Inst::Halt);
        let mut program = Program::new(1);
        program.add_stream(0, insts);
        program.validate(16).unwrap();
        let text = disassemble(&program);
        assert_eq!(assemble(&text).unwrap(), program, "case {case}");
        let words = encode_program(&program);
        assert_eq!(decode_program(&words).unwrap(), program, "case {case}");
    }
}

/// The disassembly of strategy output is human-plausible: has directives,
/// indentation, and one line per instruction.
#[test]
fn disassembly_is_structured() {
    let arch = ArchConfig::paper_default();
    let plan = SchedulePlan {
        tasks: 8,
        active_macros: 4,
        n_in: 4,
        write_speed: 8,
    };
    let program = Strategy::GeneralizedPingPong.codegen(&arch, &plan).unwrap();
    let text = disassemble(&program);
    assert!(text.starts_with(".cores 16"));
    assert_eq!(
        text.matches(".stream").count(),
        program.streams.len(),
        "one directive per stream"
    );
    let inst_lines = text
        .lines()
        .filter(|l| !l.trim_start().starts_with('.') && !l.trim().is_empty())
        .count();
    assert_eq!(inst_lines, program.len());
}

//! Integration: the steady-state fast-forward must be invisible in the
//! results.  Randomized loop programs (varying loop counts, bandwidths,
//! schedules, buffer pressure) are simulated with fast-forward on and
//! off and every `SimStats` field compared exactly; the looped codegen
//! style is checked stat-identical to the unrolled one; and the
//! cartesian DSE is checked invariant across worker counts and styles.

use gpp_pim::arch::ArchConfig;
use gpp_pim::isa::{Inst, Program};
use gpp_pim::model::dse::CartesianSpace;
use gpp_pim::sched::{CodegenStyle, SchedulePlan, Strategy};
use gpp_pim::sim::{simulate, simulate_in, SimOptions, SimWorkspace};
use gpp_pim::sweep::SweepRunner;
use gpp_pim::util::rng::XorShift64;

fn slow() -> SimOptions {
    SimOptions {
        no_fast_forward: true,
        ..SimOptions::default()
    }
}

/// A random multi-stream loop program: each stream owns one macro on its
/// own core and replays a write→compute body `count` times, with
/// optional start skew, per-iteration jitter delays and an optional
/// nested delay loop — the shapes the fast-forward has to both catch
/// (steady periods) and refuse (non-recurring transients).
fn random_loop_program(rng: &mut XorShift64) -> Program {
    let n_streams = rng.range_i64(1, 4) as usize;
    let mut program = Program::new(16);
    for si in 0..n_streams {
        let m = si as u8;
        let tile = si as u32 + 1;
        let n_vec = rng.range_i64(1, 8) as u16;
        let count = rng.range_i64(2, 60) as u32;
        let mut insts = vec![Inst::SetSpd {
            speed: rng.range_i64(1, 8) as u16,
        }];
        if rng.next_below(2) == 0 {
            insts.push(Inst::Delay {
                cycles: rng.range_i64(0, 400) as u32,
            });
        }
        insts.push(Inst::Loop { count });
        if rng.next_below(3) == 0 {
            // Nested fixed-iteration delay loop inside the body.
            insts.push(Inst::Loop {
                count: rng.range_i64(2, 5) as u32,
            });
            insts.push(Inst::Delay {
                cycles: rng.range_i64(1, 25) as u32,
            });
            insts.push(Inst::EndLoop);
        }
        insts.push(Inst::Wrw { m, tile });
        insts.push(Inst::WaitW { m });
        insts.push(Inst::LdIn { n_vec });
        insts.push(Inst::Vmm { m, n_vec, tile });
        insts.push(Inst::WaitC { m });
        insts.push(Inst::StOut { n_vec });
        if rng.next_below(3) == 0 {
            insts.push(Inst::Delay {
                cycles: rng.range_i64(0, 50) as u32,
            });
        }
        insts.push(Inst::EndLoop);
        // Occasional unrolled epilogue task after the loop.
        if rng.next_below(3) == 0 {
            insts.push(Inst::Wrw { m, tile });
            insts.push(Inst::WaitW { m });
        }
        insts.push(Inst::Halt);
        program.add_stream(si as u32, insts);
    }
    program
}

fn random_arch(rng: &mut XorShift64) -> ArchConfig {
    let mut arch = ArchConfig::paper_default();
    arch.bandwidth = 1 << rng.range_i64(0, 8); // 1..256 B/cyc
    arch.core_buffer_bytes = 1 << 22;
    arch
}

#[test]
fn randomized_loop_programs_bit_identical() {
    let mut rng = XorShift64::new(0xF457);
    let mut engaged = 0u32;
    for case in 0..40 {
        let arch = random_arch(&mut rng);
        let program = random_loop_program(&mut rng);
        let fast = simulate(&arch, &program, SimOptions::default()).unwrap();
        let slow_run = simulate(&arch, &program, slow()).unwrap();
        assert_eq!(
            fast.stats, slow_run.stats,
            "case {case}: band={} program={program:?}",
            arch.bandwidth
        );
        if fast.fast_forward.periods > 0 {
            engaged += 1;
        }
    }
    // The generator must actually exercise the fast path, not just the
    // refusal paths.  (Single-stream cases alone recur at one-iteration
    // periods; the threshold is conservative.)
    assert!(engaged >= 5, "fast-forward engaged in only {engaged}/40 cases");
}

#[test]
fn randomized_bandwidth_schedules_bit_identical() {
    let mut rng = XorShift64::new(0x5CED);
    let mut engaged = 0u32;
    for case in 0..25 {
        let arch = random_arch(&mut rng);
        let program = random_loop_program(&mut rng);
        // 1–3 sorted steps, all bands >= 1 (freeze/restore semantics are
        // pinned by sim_invariants; here the schedule's job is to gate
        // detection until it exhausts mid-run).
        let n_steps = rng.range_i64(1, 3);
        let mut cycle = 0u64;
        let mut schedule = Vec::new();
        for _ in 0..n_steps {
            cycle += rng.range_i64(100, 8000) as u64;
            schedule.push((cycle, 1 << rng.range_i64(0, 8)));
        }
        let opts = SimOptions {
            bandwidth_schedule: schedule.clone(),
            ..SimOptions::default()
        };
        let opts_slow = SimOptions {
            bandwidth_schedule: schedule,
            no_fast_forward: true,
            ..SimOptions::default()
        };
        let fast = simulate(&arch, &program, opts).unwrap();
        let slow_run = simulate(&arch, &program, opts_slow).unwrap();
        assert_eq!(fast.stats, slow_run.stats, "case {case}: {program:?}");
        if fast.fast_forward.periods > 0 {
            engaged += 1;
        }
    }
    assert!(engaged >= 2, "fast-forward engaged in only {engaged}/25 cases");
}

#[test]
fn op_log_mode_is_equivalent_and_never_skips() {
    let mut rng = XorShift64::new(0x10C);
    for _ in 0..8 {
        let arch = random_arch(&mut rng);
        let program = random_loop_program(&mut rng);
        let logged = SimOptions {
            record_op_log: true,
            ..SimOptions::default()
        };
        let logged_slow = SimOptions {
            record_op_log: true,
            no_fast_forward: true,
            ..SimOptions::default()
        };
        let a = simulate(&arch, &program, logged).unwrap();
        let b = simulate(&arch, &program, logged_slow).unwrap();
        // Op-log recording auto-disables skipping: the full timeline is
        // identical either way, and no periods were extrapolated.
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.op_log, b.op_log);
        assert_eq!(a.fast_forward.periods, 0);
        let completions = a.stats.writes_completed + a.stats.vmms_completed;
        assert_eq!(completions as usize, a.op_log.len());
    }
}

#[test]
fn looped_codegen_matches_unrolled_for_all_strategies() {
    let mut arch = ArchConfig::paper_default();
    arch.core_buffer_bytes = 1 << 22;
    for (tasks, active, n_in, band) in [
        (2048u32, 256u32, 4u32, 512u64), // the paper chip, saturated bus
        (1000, 96, 8, 128),              // ragged tasks, partial chip
        (77, 16, 2, 16),                 // narrow bus, small batch
    ] {
        arch.bandwidth = band;
        let plan = SchedulePlan {
            tasks,
            active_macros: active,
            n_in,
            write_speed: 8,
        };
        for strategy in Strategy::ALL {
            let unrolled = strategy
                .codegen_styled(&arch, &plan, CodegenStyle::Unrolled)
                .unwrap();
            let looped = strategy
                .codegen_styled(&arch, &plan, CodegenStyle::Looped)
                .unwrap();
            let a = simulate(&arch, &unrolled, SimOptions::default()).unwrap();
            let b = simulate(&arch, &looped, SimOptions::default()).unwrap();
            assert_eq!(
                a.stats, b.stats,
                "{strategy:?} tasks={tasks} active={active} n_in={n_in} band={band}"
            );
            // And the looped form must agree with its own slow path.
            let c = simulate(&arch, &looped, slow()).unwrap();
            assert_eq!(b.stats, c.stats);
        }
    }
}

#[test]
fn fast_forward_engages_on_full_chip_looped_gpp() {
    let mut arch = ArchConfig::paper_default();
    arch.core_buffer_bytes = 1 << 22;
    // Uncontended bus (>= 256 write ports x 8 B/cyc): every stream's
    // steady state recurs after exactly one iteration, so the detector
    // must engage within the 32 iterations available.
    arch.bandwidth = 4096;
    let plan = SchedulePlan {
        tasks: 8192,
        active_macros: 256,
        n_in: 4,
        write_speed: 8,
    };
    let program = Strategy::GeneralizedPingPong
        .codegen_styled(&arch, &plan, CodegenStyle::Looped)
        .unwrap();
    let fast = simulate(&arch, &program, SimOptions::default()).unwrap();
    let slow_run = simulate(&arch, &program, slow()).unwrap();
    assert_eq!(fast.stats, slow_run.stats);
    assert!(
        fast.fast_forward.periods > 0,
        "expected skipped periods on 32 iterations/stream: {:?}",
        fast.fast_forward
    );
    assert!(fast.fast_forward.cycles < fast.stats.cycles);
}

#[test]
fn fast_forward_engages_on_full_chip_looped_naive() {
    // The naive looped lowering rolls the 2-phase bank period; on an
    // uncontended bus the steady state recurs after a few pairs, so the
    // detector must skip most of the 8192-task run.
    let mut arch = ArchConfig::paper_default();
    arch.core_buffer_bytes = 1 << 22;
    arch.bandwidth = 4096;
    let plan = SchedulePlan {
        tasks: 8192,
        active_macros: 256,
        n_in: 4,
        write_speed: 8,
    };
    let program = Strategy::NaivePingPong
        .codegen_styled(&arch, &plan, CodegenStyle::Looped)
        .unwrap();
    let fast = simulate(&arch, &program, SimOptions::default()).unwrap();
    let slow_run = simulate(&arch, &program, slow()).unwrap();
    assert_eq!(fast.stats, slow_run.stats);
    assert!(
        fast.fast_forward.periods > 0,
        "expected skipped bank periods: {:?}",
        fast.fast_forward
    );
    assert!(fast.fast_forward.cycles < fast.stats.cycles);
}

#[test]
fn workspace_recycling_preserves_fast_forward_results() {
    // One workspace driven through looped, unrolled and looped programs
    // again must reproduce fresh-workspace results exactly (the detector
    // state lives in the workspace and must reset per run).
    let mut arch = ArchConfig::paper_default();
    arch.core_buffer_bytes = 1 << 22;
    let plan = SchedulePlan {
        tasks: 512,
        active_macros: 64,
        n_in: 4,
        write_speed: 8,
    };
    let looped = Strategy::GeneralizedPingPong
        .codegen_styled(&arch, &plan, CodegenStyle::Looped)
        .unwrap();
    let unrolled = Strategy::GeneralizedPingPong
        .codegen_styled(&arch, &plan, CodegenStyle::Unrolled)
        .unwrap();
    let mut ws = SimWorkspace::new();
    for program in [&looped, &unrolled, &looped, &unrolled, &looped] {
        let fresh = simulate(&arch, program, SimOptions::default()).unwrap();
        let reused = simulate_in(&arch, program, SimOptions::default(), &mut ws).unwrap();
        assert_eq!(fresh.stats, reused.stats);
    }
}

#[test]
fn cartesian_dse_invariant_across_jobs_and_styles() {
    let base = ArchConfig::paper_default();
    let space = CartesianSpace {
        cores: vec![2, 8],
        macros_per_core: vec![4, 16],
        n_in: vec![2, 8],
        bandwidths: vec![32, 256],
        buffers: vec![64 * 1024],
        tasks: 512,
        write_speed: 8,
    };
    let par_runner = SweepRunner::new(8);
    let looped_par = space.sweep(&base, &par_runner, CodegenStyle::Looped).unwrap();
    // The cartesian sweep dispatches in (strategy, plan)-sorted order
    // for codegen-cache locality; the sort must only reorder work, not
    // change what is cached — one entry per distinct (strategy, plan,
    // arch) key, i.e. 16 combos x 3 strategies here.
    assert_eq!(par_runner.cache().len(), 16 * 3, "grouped dispatch changed cache population");
    assert_eq!(par_runner.cache().misses(), 16 * 3);
    let looped_seq = space
        .sweep(&base, &SweepRunner::sequential(), CodegenStyle::Looped)
        .unwrap();
    let unrolled = space
        .sweep(&base, &SweepRunner::new(3), CodegenStyle::Unrolled)
        .unwrap();
    assert_eq!(looped_par, looped_seq);
    assert_eq!(looped_par, unrolled);
    assert_eq!(looped_par.len(), 16);
    assert!(looped_par.iter().all(|p| p.feasible()));
    // GPP must never lose meaningfully to in-situ on a feasible point.
    // Slack covers the stagger prologue: on an uncontended bus gpp pays
    // up to one extra period over `tasks/active` iterations, which at
    // active=128 / tasks=512 is ~20% — the steady-state win only
    // materializes once the bus is the bottleneck.
    for p in &looped_par {
        let (i, g) = (p.cycles[0].unwrap(), p.cycles[2].unwrap());
        assert!(
            g as f64 <= i as f64 * 1.30,
            "gpp {g} vs insitu {i} at {p:?}"
        );
    }
}

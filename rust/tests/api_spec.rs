//! `RunSpec` grammar contract: every spec string's `Display` output
//! re-parses to an equal value (the `exec` acceptance criterion), and
//! malformed specs are rejected with messages naming the valid keys.

use gpp_pim::api::{RunSpec, SpecError, VALID_KINDS};
use gpp_pim::fleet::PlacementPolicy;
use gpp_pim::sched::{CodegenStyle, Strategy};

/// Parse → Display → parse must be the identity on the parsed value,
/// and Display must be a fixed point (canonical form).
fn roundtrip(spec: &str) -> RunSpec {
    let parsed = RunSpec::parse(spec).unwrap_or_else(|e| panic!("'{spec}' rejected: {e}"));
    let printed = parsed.to_string();
    let reparsed = RunSpec::parse(&printed)
        .unwrap_or_else(|e| panic!("display '{printed}' of '{spec}' rejected: {e}"));
    assert_eq!(parsed, reparsed, "'{spec}' -> '{printed}' changed meaning");
    assert_eq!(
        reparsed.to_string(),
        printed,
        "display of '{spec}' is not canonical"
    );
    parsed
}

#[test]
fn every_kind_roundtrips_with_typical_keys() {
    for spec in [
        "repro",
        "repro:exp=fig7:vectors=2048:jobs=4",
        "run",
        "run:workload=mlp:strategy=insitu:numerics=true",
        "run:trace=traces/a.txt:artifacts=out",
        "simulate",
        "simulate:strategy=naive:tasks=512:macros=16:nin=8:band=64:s=4:oplog=true",
        "serve",
        "serve:requests=512:seed=3:gap=4096:jobs=8:placement=affinity:chips=4",
        "serve:fleet=2xpaper,1xpaper:band=256",
        "serve:fleet=2xpaper:placement=least-loaded:requests=512",
        "fleet",
        "fleet:sizes=2,4:placement=rr,least-loaded:requests=64",
        "fleet:fleet=1xpaper,1xfig4",
        "dse",
        "dse:band=256:top=5",
        "dse:sim=true:tasks=512:jobs=2:top=3",
        "dse-full",
        "dse-full:cores=2,4:macros=2,4:nin=2,4:bands=32,64:buffers=65536:tasks=512:top=5",
        "dse-full:style=unrolled:s=4",
        "dse-full:fleets=1,2,4:placement=all:requests=64:seed=9:gap=512",
        "adapt",
        "adapt:maxn=128",
    ] {
        roundtrip(spec);
    }
}

#[test]
fn issue_example_is_the_canonical_form() {
    let spec = roundtrip("serve:fleet=2xpaper:placement=least-loaded:requests=512");
    // Canonical order: requests before placement before fleet.
    assert_eq!(
        spec.to_string(),
        "serve:requests=512:placement=least-loaded:fleet=2xpaper"
    );
}

#[test]
fn typed_construction_displays_and_reparses() {
    // The embedder direction: build typed, print, parse back.
    let RunSpec::DseFull(mut full) = RunSpec::parse("dse-full").unwrap() else {
        panic!()
    };
    full.cores = Some(vec![2, 8]);
    full.style = CodegenStyle::Unrolled;
    full.fleets = vec![1, 2];
    full.placements = vec![PlacementPolicy::ClassAffinity];
    let spec = RunSpec::DseFull(full);
    let reparsed = RunSpec::parse(&spec.to_string()).unwrap();
    assert_eq!(spec, reparsed);

    let RunSpec::Simulate(mut sim) = RunSpec::parse("simulate").unwrap() else {
        panic!()
    };
    sim.strategy = Strategy::IntraMacroPingPong;
    sim.n_in = Some(2);
    let spec = RunSpec::Simulate(sim);
    assert_eq!(RunSpec::parse(&spec.to_string()).unwrap(), spec);
}

#[test]
fn ignored_fields_never_render_an_unparsable_spec() {
    // A typed-constructed serve spec may carry chips next to a set
    // fleet (fleet wins; the parser rejects the pair as a typo guard).
    // Display must drop the ignored field so its output re-parses
    // cleanly to the same effective experiment.
    let RunSpec::Serve(mut serve) = RunSpec::parse("serve").unwrap() else {
        panic!()
    };
    serve.chips = 4;
    serve.fleet = Some("2xpaper".into());
    let printed = RunSpec::Serve(serve).to_string();
    assert_eq!(printed, "serve:fleet=2xpaper");
    let RunSpec::Serve(reparsed) = RunSpec::parse(&printed).unwrap() else {
        panic!()
    };
    assert_eq!(reparsed.fleet.as_deref(), Some("2xpaper"));

    // Same for fleet-sweep sizes vs an explicit fleet.
    let RunSpec::FleetSweep(mut fs) = RunSpec::parse("fleet").unwrap() else {
        panic!()
    };
    fs.sizes = vec![8];
    fs.fleet = Some("1xfig4".into());
    let printed = RunSpec::FleetSweep(fs).to_string();
    assert_eq!(printed, "fleet:fleet=1xfig4");
    assert!(RunSpec::parse(&printed).is_ok());
}

#[test]
fn kind_names_are_stable() {
    for kind in VALID_KINDS {
        assert_eq!(RunSpec::parse(kind).unwrap().kind(), kind);
        assert!(
            !RunSpec::valid_keys(kind).is_empty(),
            "kind '{kind}' lists no keys"
        );
    }
}

#[test]
fn rejections_name_the_valid_keys() {
    // A typo'd key must be rejected — and the error must teach the
    // valid key set (the CLI-hardening contract).
    let err = RunSpec::parse("serve:reqests=512").unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("reqests"), "{msg}");
    assert!(
        msg.contains("requests, seed, gap, jobs, placement, faults, autoscale, slo, chips, fleet"),
        "{msg}"
    );

    let err = RunSpec::parse("bogus:x=1").unwrap_err();
    assert!(err.to_string().contains("repro, run, simulate"), "{err}");

    assert_eq!(RunSpec::parse(""), Err(SpecError::Empty));
}

#[test]
fn degenerate_values_are_rejected() {
    for bad in [
        "serve:jobs=0",
        "serve:chips=0",
        "serve:requests=x",
        "serve:placement=nope",
        "serve:fleet=0xpaper",
        "serve:chips=2:fleet=2xpaper",
        "fleet:sizes=1,0",
        "fleet:sizes=2:fleet=2xpaper",
        "dse:top=0",
        "dse:sim=maybe",
        "dse-full:cores=0,2",
        "dse-full:bands=",
        "dse-full:tasks=0",
        "dse-full:style=fast",
        "simulate:strategy=warp",
        "simulate:oplog=2",
        "run:workload=doom",
        "repro:exp=fig99",
        "repro:vectors=-1",
        "adapt:maxn=x",
        "serve:requests",
    ] {
        assert!(RunSpec::parse(bad).is_err(), "accepted '{bad}'");
    }
}

//! Serve determinism: the serving report must be a pure function of
//! `(traffic, arch)` — independent of host worker count and chip-replica
//! count — because the report CSVs are the artifact users diff and the
//! perf-gate compares byte-for-byte in CI.

use gpp_pim::arch::ArchConfig;
use gpp_pim::serve::{synthetic_traffic, Batcher, Request, ServeEngine, TrafficConfig};

fn arch() -> ArchConfig {
    ArchConfig::paper_default()
}

fn traffic(requests: u32, seed: u64) -> Vec<Request> {
    synthetic_traffic(
        &arch(),
        &TrafficConfig {
            requests,
            seed,
            mean_gap_cycles: 2048,
            ..Default::default()
        },
    )
}

/// Per-request CSV + summary CSV: the full byte-comparison surface.
fn report_csv(jobs: usize, chips: usize, requests: &[Request]) -> String {
    let report = ServeEngine::new(arch(), jobs, chips).run(requests).unwrap();
    format!(
        "{}{}",
        report.to_table().to_csv(),
        report.summary_table().to_csv()
    )
}

#[test]
fn same_seed_same_jobs_byte_identical() {
    let reqs = traffic(96, 7);
    assert_eq!(report_csv(1, 1, &reqs), report_csv(1, 1, &reqs));
}

#[test]
fn jobs_1_vs_n_byte_identical() {
    let reqs = traffic(96, 7);
    let base = report_csv(1, 1, &reqs);
    for jobs in [2usize, 4, 16] {
        assert_eq!(base, report_csv(jobs, 1, &reqs), "jobs={jobs} diverged");
    }
}

#[test]
fn chips_1_vs_2_remerge_byte_identical() {
    let reqs = traffic(96, 7);
    let base = report_csv(4, 1, &reqs);
    for chips in [2usize, 3, 8] {
        assert_eq!(base, report_csv(4, chips, &reqs), "chips={chips} diverged");
    }
}

#[test]
fn different_seeds_produce_different_reports() {
    // Guards against the degenerate "deterministic because constant" bug.
    let a = report_csv(2, 1, &traffic(64, 7));
    let b = report_csv(2, 1, &traffic(64, 8));
    assert_ne!(a, b);
}

#[test]
fn batcher_shares_codegen_across_requests_in_one_class() {
    // Classes with identical (strategy, plan, arch) must share one
    // codegen cache entry: an engine reused across identical streams
    // generates zero new programs and serves every class from cache.
    let reqs = traffic(128, 7);
    let set = Batcher::new(arch()).batch(&reqs).unwrap();
    assert!(
        set.classes() < reqs.len() / 2,
        "traffic must fold {} requests into fewer than {} classes (got {})",
        reqs.len(),
        reqs.len() / 2,
        set.classes()
    );

    let engine = ServeEngine::new(arch(), 4, 1);
    engine.run(&reqs).unwrap();
    assert_eq!(
        engine.cache().misses(),
        set.classes() as u64,
        "exactly one generated program per class"
    );
    assert_eq!(engine.cache().hits(), 0);

    engine.run(&reqs).unwrap();
    assert_eq!(
        engine.cache().misses(),
        set.classes() as u64,
        "re-serving the stream must not generate new programs"
    );
    assert_eq!(
        engine.cache().hits(),
        set.classes() as u64,
        "every class must hit the shared cache entry on re-serve"
    );
}

#[test]
fn oversubscribed_engine_is_fine() {
    // More workers than classes: the work-stealing loop must neither
    // deadlock nor drop classes.
    let reqs = traffic(24, 3);
    let report = ServeEngine::new(arch(), 64, 2).run(&reqs).unwrap();
    assert_eq!(report.requests(), 24);
}

//! Overload-control and bandwidth-throttle acceptance (ISSUE 9):
//!
//! - a throttle epoch that closes (restore to 100%) before the first
//!   arrival is byte-inert on every placement policy — throttling is
//!   pure pricing, and a fully-restored plan prices nothing;
//! - burst traffic + a throttle storm + admission control yields
//!   byte-identical policy CSVs across `--jobs` settings;
//! - deadline expiry and retry counts are deterministic, with the
//!   served + shed + expired + dropped == total accounting invariant
//!   holding on every run;
//! - the bounded exponential backoff sequence is a pure function of the
//!   attempt index — stable across seeds, jobs, and reruns.

use gpp_pim::arch::ArchConfig;
use gpp_pim::fleet::{FaultPlan, FleetConfig, OverloadConfig, PlacementPolicy};
use gpp_pim::serve::{synthetic_traffic, Request, ServeEngine, TrafficConfig, TrafficShape};

fn arch() -> ArchConfig {
    ArchConfig::paper_default()
}

fn fleet(n: usize) -> FleetConfig {
    FleetConfig::homogeneous(arch(), n)
}

/// Burst arrivals — the overload stressor shape.
fn burst(requests: u32, mean_gap: u64) -> Vec<Request> {
    synthetic_traffic(
        &arch(),
        &TrafficConfig {
            requests,
            seed: 7,
            mean_gap_cycles: mean_gap,
            shape: TrafficShape::Burst,
        },
    )
}

/// The full policy-timeline byte surface: per-chip table, per-request
/// table, and the summary with the overload counters.
fn policy_csv(engine: &ServeEngine, reqs: &[Request]) -> String {
    let r = engine.run(reqs).unwrap();
    format!(
        "{}{}{}",
        r.fleet.to_table().to_csv(),
        r.fleet.requests_table().to_csv(),
        r.summary_table().to_csv()
    )
}

#[test]
fn restored_throttle_plan_is_byte_identical_to_no_fault_for_every_policy() {
    // Shift every arrival to >= 10 so the throttle epoch [0, 5) provably
    // closes before any placement: nothing is ever priced under it.
    let mut reqs = burst(64, 2048);
    for r in &mut reqs {
        r.arrival_cycle += 10;
    }
    let plan = FaultPlan::parse("throttle@0@0@25,restore@5@0").unwrap();
    for policy in PlacementPolicy::ALL {
        let plain = policy_csv(&ServeEngine::with_fleet(fleet(2), policy, 4), &reqs);
        let restored = policy_csv(
            &ServeEngine::with_fleet(fleet(2), policy, 4).with_faults(plan.clone()),
            &reqs,
        );
        assert_eq!(
            plain,
            restored,
            "policy {}: a restored-before-traffic throttle must be byte-inert",
            policy.name()
        );
    }
}

#[test]
fn throttle_storm_with_overload_is_jobs_invariant_under_burst() {
    let reqs = burst(96, 1024);
    let storm = || {
        FaultPlan::parse("throttle@1000@0@25,throttle@30000@1@50,restore@400000@0,mtbf@500000@9")
            .unwrap()
    };
    let overload = OverloadConfig::with_queue_cap(2);
    for policy in PlacementPolicy::ALL {
        let base = policy_csv(
            &ServeEngine::with_fleet(fleet(4), policy, 1)
                .with_faults(storm())
                .with_overload(overload),
            &reqs,
        );
        for jobs in [2usize, 8] {
            assert_eq!(
                base,
                policy_csv(
                    &ServeEngine::with_fleet(fleet(4), policy, jobs)
                        .with_faults(storm())
                        .with_overload(overload),
                    &reqs
                ),
                "policy {} diverged under throttle storm + overload at jobs={jobs}",
                policy.name()
            );
        }
    }
}

#[test]
fn throttle_epoch_stretches_service_and_the_reference_never_moves() {
    let reqs = burst(48, 1024);
    let plain = ServeEngine::with_fleet(fleet(1), PlacementPolicy::RoundRobin, 4)
        .run(&reqs)
        .unwrap();
    // Throttle the only chip to 1% for the whole run: every placement
    // is repriced under the reduced envelope.
    let throttled = ServeEngine::with_fleet(fleet(1), PlacementPolicy::RoundRobin, 4)
        .with_faults(FaultPlan::parse("throttle@0@0@1").unwrap())
        .run(&reqs)
        .unwrap();
    // Reference timeline (serve.csv) is fault-invariant by contract.
    assert_eq!(plain.to_table().to_csv(), throttled.to_table().to_csv());
    // The policy timeline stretched: same requests served, longer tail.
    assert_eq!(
        throttled.fleet.assignments.iter().filter(|a| !a.dropped).count(),
        reqs.len(),
        "throttling must not drop anything"
    );
    assert!(
        throttled.fleet.makespan > plain.fleet.makespan,
        "a 1% envelope must stretch the makespan ({} vs {})",
        throttled.fleet.makespan,
        plain.fleet.makespan
    );
}

#[test]
fn deadline_expiry_and_retry_counts_are_deterministic() {
    // One chip, dense bursts: heavy overload by construction.
    let reqs = burst(32, 512);
    let overload = OverloadConfig {
        queue_cap: Some(1),
        deadline: Some(4096),
    };
    let run = |jobs: usize| {
        ServeEngine::with_fleet(fleet(1), PlacementPolicy::LeastLoaded, jobs)
            .with_overload(overload)
            .run(&reqs)
            .unwrap()
    };
    let a = run(1);
    let f = &a.fleet;
    // The cap and the deadline both bite on this stream.
    assert!(f.faults.shed > 0, "cap 1 under bursts must shed");
    assert!(f.faults.retries > 0, "shedding implies backoff retries");
    // Accounting invariant: every request lands in exactly one terminal
    // state.
    assert_eq!(
        f.goodput() + f.faults.shed as u64 + f.faults.expired as u64 + f.faults.dropped as u64,
        reqs.len() as u64,
        "served + shed + expired + dropped must cover the trace"
    );
    // Per-request budgets: nobody retries past the cap, and the flags
    // are mutually exclusive terminal states.
    for x in &f.assignments {
        assert!(x.retries <= OverloadConfig::MAX_RETRIES);
        assert!(!(x.shed && x.expired), "request {} shed AND expired", x.id);
        if x.shed || x.expired {
            assert!(x.dropped, "terminal overload states count as dropped");
        }
    }
    // Determinism: jobs 2 and 8 reproduce the identical outcome,
    // counter for counter and byte for byte.
    for jobs in [2usize, 8] {
        let b = run(jobs);
        assert_eq!(f.faults.shed, b.fleet.faults.shed, "jobs={jobs}");
        assert_eq!(f.faults.expired, b.fleet.faults.expired, "jobs={jobs}");
        assert_eq!(f.faults.retries, b.fleet.faults.retries, "jobs={jobs}");
        assert_eq!(
            f.requests_table().to_csv(),
            b.fleet.requests_table().to_csv(),
            "jobs={jobs}"
        );
    }
}

#[test]
fn deadline_alone_expires_without_shedding() {
    let reqs = burst(32, 512);
    let report = ServeEngine::with_fleet(fleet(1), PlacementPolicy::RoundRobin, 4)
        .with_overload(OverloadConfig::with_deadline(1))
        .run(&reqs)
        .unwrap();
    let f = &report.fleet;
    // Unbounded queues: nothing is shed, but a 1-cycle deadline expires
    // everything that is not at the head of its burst.
    assert_eq!(f.faults.shed, 0);
    assert!(f.faults.expired > 0, "deadline 1 must expire queued bursts");
    assert_eq!(
        f.goodput() + f.faults.expired as u64 + f.faults.dropped as u64,
        reqs.len() as u64
    );
}

#[test]
fn backoff_sequence_is_a_pure_function_of_the_attempt() {
    // Doubling from the base, capped — no seed, clock, or worker-count
    // input anywhere in the signature.
    assert_eq!(OverloadConfig::backoff(1), 256);
    assert_eq!(OverloadConfig::backoff(2), 512);
    assert_eq!(OverloadConfig::backoff(3), 1024);
    assert_eq!(OverloadConfig::backoff(63), OverloadConfig::BACKOFF_CAP);
    assert_eq!(OverloadConfig::backoff(64), OverloadConfig::BACKOFF_CAP);
    // Cumulative wake-ups for a request shed at cycle 0: the documented
    // deterministic schedule.
    let mut due = 0u64;
    let dues: Vec<u64> = (1..=OverloadConfig::MAX_RETRIES)
        .map(|k| {
            due += OverloadConfig::backoff(k);
            due
        })
        .collect();
    assert_eq!(dues, vec![256, 768, 1792]);
    // Seed-stability at the engine level: different traffic seeds leave
    // the backoff-derived retry budget identical (MAX_RETRIES per shed
    // request), and a rerun of the same seed is byte-identical.
    for seed in [3u64, 7, 11] {
        let reqs = synthetic_traffic(
            &arch(),
            &TrafficConfig {
                requests: 24,
                seed,
                mean_gap_cycles: 512,
                shape: TrafficShape::Burst,
            },
        );
        let run = || {
            ServeEngine::with_fleet(fleet(1), PlacementPolicy::RoundRobin, 4)
                .with_overload(OverloadConfig::with_queue_cap(1))
                .run(&reqs)
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(
            a.fleet.requests_table().to_csv(),
            b.fleet.requests_table().to_csv(),
            "seed {seed}: rerun must be byte-identical"
        );
        for x in &a.fleet.assignments {
            if x.shed {
                assert_eq!(
                    x.retries,
                    OverloadConfig::MAX_RETRIES,
                    "seed {seed}: a terminally shed request exhausts its budget"
                );
            }
        }
    }
}

//! Golden byte-identity: the `api::Session` pipeline must produce the
//! same CSV bytes as the pre-API entry points.  The "old path" here is
//! either the underlying machinery driven directly (figures, serve
//! engine) or a verbatim replica of the table-building loops the CLI
//! subcommands used to inline — so a façade regression cannot hide
//! behind a shared helper.

use gpp_pim::api::{MemorySink, RunSpec, Session, SinkSet};
use gpp_pim::arch::ArchConfig;
use gpp_pim::fleet::{FleetConfig, PlacementPolicy};
use gpp_pim::model::dse::{CartesianSpace, DesignSpace};
use gpp_pim::report::figures as figs;
use gpp_pim::sched::CodegenStyle;
use gpp_pim::serve::{run_fleet_axis, synthetic_traffic, ServeEngine, TrafficConfig};
use gpp_pim::sweep::{top_k_by, FleetAxis, SweepRunner};
use gpp_pim::util::csv::CsvTable;

fn capture(spec: &str) -> MemorySink {
    let session = Session::with_jobs(ArchConfig::paper_default(), 2);
    let mut mem = MemorySink::new();
    session
        .run(
            &RunSpec::parse(spec).unwrap(),
            &mut SinkSet::new().with(&mut mem),
        )
        .unwrap();
    mem
}

#[test]
fn repro_fig4_matches_direct_figures_path() {
    let mem = capture("repro:exp=fig4");
    let runner = SweepRunner::new(2);
    let expected = figs::fig4_table(&figs::fig4_with(&runner).unwrap()).to_csv();
    assert_eq!(mem.csv("fig4").unwrap(), expected);
}

#[test]
fn repro_headline_matches_direct_figures_path() {
    let mem = capture("repro:exp=headline:vectors=2048");
    let runner = SweepRunner::new(2);
    let expected = figs::headline_table(&figs::headline_with(&runner, 2048).unwrap()).to_csv();
    assert_eq!(mem.csv("headline").unwrap(), expected);
}

#[test]
fn serve_heterogeneous_fleet_matches_direct_engine_path() {
    let mem = capture(
        "serve:requests=48:seed=7:gap=1024:placement=affinity:fleet=1xpaper,1xpaper:band=256",
    );
    let arch = ArchConfig::paper_default();
    let fleet = FleetConfig::parse("1xpaper,1xpaper:band=256", &arch).unwrap();
    let engine = ServeEngine::with_fleet(fleet, PlacementPolicy::ClassAffinity, 2);
    let requests = synthetic_traffic(
        engine.arch(),
        &TrafficConfig {
            requests: 48,
            seed: 7,
            mean_gap_cycles: 1024,
            ..Default::default()
        },
    );
    let report = engine.run(&requests).unwrap();
    assert_eq!(mem.csv("serve").unwrap(), report.to_table().to_csv());
    assert_eq!(mem.csv("serve_summary").unwrap(), report.summary_table().to_csv());
    assert_eq!(mem.csv("fleet").unwrap(), report.fleet.to_table().to_csv());
    assert_eq!(
        mem.csv("fleet_requests").unwrap(),
        report.fleet.requests_table().to_csv()
    );
}

#[test]
fn dse_model_table_matches_pre_api_bytes() {
    let mem = capture("dse:top=3");
    // Verbatim replica of the pre-API `cmd_dse` model-path table code.
    let mut arch = ArchConfig::paper_default();
    arch.bandwidth = 128;
    let mut space = DesignSpace::fig6(&arch);
    space.bandwidth = arch.bandwidth as f64;
    let pts = space.sweep_fig6();
    let mut t = CsvTable::new(vec![
        "tr:tp",
        "n_in",
        "macros_insitu",
        "macros_naive",
        "macros_gpp",
        "eff_insitu",
        "eff_naive",
        "eff_gpp",
        "peak_bw_gpp",
    ]);
    for p in &pts {
        t.push_row(vec![
            format!("{:.3}", p.ratio_tr_over_tp),
            format!("{:.1}", space.n_in_for_ratio(p.ratio_tr_over_tp)),
            format!("{:.1}", p.insitu.num_macros),
            format!("{:.1}", p.naive.num_macros),
            format!("{:.1}", p.gpp.num_macros),
            format!("{:.1}", p.insitu.effective_macros),
            format!("{:.1}", p.naive.effective_macros),
            format!("{:.1}", p.gpp.effective_macros),
            format!("{:.1}", p.gpp.peak_bandwidth),
        ]);
    }
    assert_eq!(mem.csv("dse").unwrap(), t.to_csv());
    let k = top_k_by(pts.len(), 3, |i| pts[i].gpp.exec_cycles);
    let mut tk = CsvTable::new(vec![
        "rank", "index", "tr:tp", "n_in", "macros_gpp", "exec_cycles_gpp",
    ]);
    for (rank, &i) in k.iter().enumerate() {
        let p = &pts[i];
        tk.push_row(vec![
            (rank + 1).to_string(),
            i.to_string(),
            format!("{:.3}", p.ratio_tr_over_tp),
            format!("{:.1}", space.n_in_for_ratio(p.ratio_tr_over_tp)),
            format!("{:.1}", p.gpp.num_macros),
            format!("{:.1}", p.gpp.exec_cycles),
        ]);
    }
    assert_eq!(mem.csv("dse_topk").unwrap(), tk.to_csv());
}

#[test]
fn dse_full_tables_match_pre_api_bytes() {
    let mem = capture(
        "dse-full:cores=2,4:macros=2,4:nin=2,16:bands=16,64:buffers=4096,65536:tasks=64:top=5",
    );
    // Verbatim replica of the pre-API `cmd_dse_full` table code (same
    // axes; the 4 KiB x n_in=16 corner is infeasible by design, so the
    // empty-cell formatting is exercised too).
    let arch = ArchConfig::paper_default();
    let space = CartesianSpace {
        cores: vec![2, 4],
        macros_per_core: vec![2, 4],
        n_in: vec![2, 16],
        bandwidths: vec![16, 64],
        buffers: vec![4096, 65536],
        tasks: 64,
        write_speed: arch.write_speed,
    };
    let runner = SweepRunner::new(2);
    let pts = space.sweep(&arch, &runner, CodegenStyle::Looped).unwrap();
    assert!(pts.iter().any(|p| !p.feasible()), "corner must be infeasible");
    let mut t = CsvTable::new(vec![
        "cores",
        "macros_per_core",
        "n_in",
        "band",
        "buffer",
        "feasible",
        "cycles_insitu",
        "cycles_naive",
        "cycles_gpp",
        "gpp/insitu",
    ]);
    let cell = |c: Option<u64>| c.map(|v| v.to_string()).unwrap_or_default();
    for p in &pts {
        let ratio = match (p.cycles[0], p.cycles[2]) {
            (Some(i), Some(g)) if g > 0 => format!("{:.2}", i as f64 / g as f64),
            _ => String::new(),
        };
        t.push_row(vec![
            p.cores.to_string(),
            p.macros_per_core.to_string(),
            p.n_in.to_string(),
            p.bandwidth.to_string(),
            p.buffer_bytes.to_string(),
            p.feasible().to_string(),
            cell(p.cycles[0]),
            cell(p.cycles[1]),
            cell(p.cycles[2]),
            ratio,
        ]);
    }
    assert_eq!(mem.csv("dse_full").unwrap(), t.to_csv());

    let feasible_idx: Vec<usize> = pts
        .iter()
        .enumerate()
        .filter(|(_, p)| p.feasible())
        .map(|(i, _)| i)
        .collect();
    let k = top_k_by(feasible_idx.len(), 5, |j| {
        pts[feasible_idx[j]].cycles[2].unwrap() as f64
    });
    let mut tk = CsvTable::new(vec![
        "rank",
        "index",
        "cores",
        "macros_per_core",
        "n_in",
        "band",
        "buffer",
        "cycles_gpp",
        "gpp/insitu",
    ]);
    for (rank, &j) in k.iter().enumerate() {
        let i = feasible_idx[j];
        let p = &pts[i];
        tk.push_row(vec![
            (rank + 1).to_string(),
            i.to_string(),
            p.cores.to_string(),
            p.macros_per_core.to_string(),
            p.n_in.to_string(),
            p.bandwidth.to_string(),
            p.buffer_bytes.to_string(),
            p.cycles[2].unwrap().to_string(),
            format!("{:.2}", p.cycles[0].unwrap() as f64 / p.cycles[2].unwrap() as f64),
        ]);
    }
    assert_eq!(mem.csv("dse_topk").unwrap(), tk.to_csv());

    // The Pareto table only contains feasible, non-dominated points and
    // every one of them also appears in dse_full.
    let pareto = mem.csv("dse_pareto").unwrap();
    assert!(pareto.lines().count() > 1);
    for line in pareto.lines().skip(1) {
        let idx: usize = line.split(',').next().unwrap().parse().unwrap();
        assert!(feasible_idx.contains(&idx), "pareto row {idx} not feasible");
    }
}

#[test]
fn fleet_axis_table_matches_pre_api_bytes() {
    let mem = capture("fleet:requests=24:seed=7:gap=1024:sizes=1,2:placement=all");
    // Verbatim replica of the pre-API `cmd_fleet` table code.
    let arch = ArchConfig::paper_default();
    let requests = synthetic_traffic(
        &arch,
        &TrafficConfig {
            requests: 24,
            seed: 7,
            mean_gap_cycles: 1024,
            ..Default::default()
        },
    );
    let fleets: Vec<FleetConfig> = [1usize, 2]
        .iter()
        .map(|&n| FleetConfig::homogeneous(arch.clone(), n))
        .collect();
    let axis = FleetAxis::new(fleets, PlacementPolicy::ALL.to_vec());
    let rows = run_fleet_axis(&axis, &requests, 2).unwrap();
    let mut t = CsvTable::new(vec![
        "fleet",
        "chips",
        "policy",
        "p50_latency",
        "p95_latency",
        "p99_latency",
        "mean_latency",
        "makespan",
        "speedup",
        "max_utilization",
    ]);
    for (point, report) in &rows {
        let f = &report.fleet;
        let pcts = f.latency_percentiles(&[50.0, 95.0, 99.0]);
        let max_util = (0..f.chips())
            .map(|c| f.utilization(c))
            .fold(0.0f64, f64::max);
        t.push_row(vec![
            point.fleet.describe(),
            point.fleet.len().to_string(),
            point.policy.name().to_string(),
            pcts[0].to_string(),
            pcts[1].to_string(),
            pcts[2].to_string(),
            f.mean_latency().to_string(),
            f.makespan.to_string(),
            format!("{:.2}", report.fleet_speedup()),
            format!("{max_util:.4}"),
        ]);
    }
    assert_eq!(mem.csv("fleet_axis").unwrap(), t.to_csv());
}
